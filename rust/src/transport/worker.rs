//! The standalone shard-compute worker behind `cdc-dnn worker`.
//!
//! A worker binds a TCP port, loads an artifact set (python-built or
//! `cdc-dnn synth`), and serves one coordinator connection at a time:
//! Deploy frames install task definitions (weights included), Work
//! frames execute batched GEMM orders through the shared [`Runtime`]
//! (interpreter by default), and Reply frames stream back. Between
//! coordinator sessions the worker returns to its accept loop with a
//! clean slate, so a single long-lived worker serves many sessions.
//!
//! ## Failure + delay emulation
//!
//! Real deployments misbehave; the worker can be told to, too:
//!
//! * `SetFailure` installs a `fleet::FailurePlan`; a dropped reply is
//!   **silence** (the frame is simply not sent), so the coordinator's
//!   deadline reaper — not a polite error — detects it, exactly like a
//!   lossy WLAN. Drop draws reuse the fleet's content-addressed RNG
//!   keyed on `(seed, device, first task, input bits)`, so a scripted
//!   drop pattern replays identically in sim and tcp modes.
//! * `SetNet` (or `--net` on the CLI) applies a `fleet::net` profile as
//!   artificial reply delay, sampled per reply from the same
//!   distributions the simulator uses.
//! * `SetRate` (or `--rate`) emulates RPi-class compute: each task
//!   sleeps `batch × macs / rate` ms before replying, making loopback
//!   wall-clock behaviour resemble the paper's testbed instead of a
//!   laptop's microseconds.

use std::collections::HashMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::fleet::{self, FailurePlan, NetConfig};
use crate::kernels::{PackedWeights, QuantWeights};
use crate::rng::Pcg32;
use crate::runtime::{Manifest, Runtime};
use crate::tensor::Tensor;

use super::evloop::lock;
use super::wire::{self, Frame, WireTask};

/// Worker launch options (`cdc-dnn worker` CLI flags).
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Bind address; port 0 picks an ephemeral port (printed on stdout
    /// as `cdc-dnn worker listening on <addr>` for harnesses to parse).
    pub listen: String,
    /// Artifact set root (`manifest.json` + weights).
    pub artifacts: PathBuf,
    /// Optional artificial reply-delay profile applied from startup.
    pub net: Option<NetConfig>,
    /// Optional artificial compute rate (MACs/ms) applied from startup.
    pub rate_macs_per_ms: Option<f64>,
    /// Join mode: dial this coordinator membership port and `Register`
    /// instead of binding a listener (DESIGN.md §13). The worker serves
    /// that one session and exits when the coordinator closes it.
    pub join: Option<String>,
    /// Send a graceful `Leave` this many ms after a session starts,
    /// then keep serving in-flight orders until the coordinator drains
    /// and closes the connection.
    pub leave_after_ms: Option<u64>,
}

impl WorkerOptions {
    /// Defaults: ephemeral loopback port, `artifacts/`, no emulation.
    pub fn new(artifacts: impl Into<PathBuf>) -> WorkerOptions {
        WorkerOptions {
            listen: "127.0.0.1:0".into(),
            artifacts: artifacts.into(),
            net: None,
            rate_macs_per_ms: None,
            join: None,
            leave_after_ms: None,
        }
    }
}

/// The line prefix a worker prints once bound — harnesses parse the
/// address after it.
pub const LISTENING_PREFIX: &str = "cdc-dnn worker listening on ";

/// A deployed task's resident weights (DESIGN.md §15): the f32 tensor
/// with locally rebuilt packed panels, or the int8 form as shipped.
/// Packed panels are never on the wire — their layout is arch-local —
/// so each worker rebuilds them once at Deploy receipt.
enum TaskWeights {
    F32 {
        w: Tensor,
        /// Built when the shape can ever take the blocked kernel
        /// ([`PackedWeights::pays_off`]); `None` keeps the naive path.
        packed: Option<PackedWeights>,
    },
    Int8 {
        quant: QuantWeights,
    },
}

struct WorkerTask {
    artifact: String,
    macs: u64,
    reply_bytes: u64,
    weights: TaskWeights,
    b: Tensor,
}

/// Cumulative per-session worker counters, piggybacked on each
/// `HeartbeatAck` to a proto ≥ 4 coordinator (DESIGN.md §16). Plain
/// integers: everything that touches them runs on the frame loop.
#[derive(Default)]
struct WorkerCounters {
    orders: u64,
    replies: u64,
    dropped: u64,
    exec_errors: u64,
}

impl WorkerCounters {
    /// The on-wire `(id, value)` snapshot for [`wire::heartbeat_ack_with_counters`].
    fn snapshot(&self) -> [(u8, u64); wire::WCTR_SLOTS] {
        [
            (wire::WCTR_ORDERS, self.orders),
            (wire::WCTR_REPLIES, self.replies),
            (wire::WCTR_DROPPED, self.dropped),
            (wire::WCTR_EXEC_ERRORS, self.exec_errors),
        ]
    }
}

/// Per-connection session state, reset for every coordinator.
struct ConnState {
    seed: u64,
    device: usize,
    /// The coordinator's announced protocol version (from Hello or
    /// RegisterAck); decides whether HeartbeatAck carries counters.
    peer_proto: u16,
    tasks: HashMap<u64, WorkerTask>,
    failure: FailurePlan,
    net: Option<NetConfig>,
    rate: Option<f64>,
    counters: WorkerCounters,
}

/// Run a worker until its process is killed or a Shutdown frame
/// arrives. Blocks forever on the accept loop otherwise. With
/// `opts.join` set, dials the coordinator instead and serves that one
/// session.
pub fn run(opts: &WorkerOptions) -> Result<()> {
    let manifest = Manifest::load(&opts.artifacts)?;
    let runtime = Runtime::new()?;
    if let Some(addr) = &opts.join {
        return run_joined(addr, &runtime, &manifest, opts);
    }
    let listener = TcpListener::bind(&opts.listen)
        .map_err(|e| Error::Wire(format!("bind {}: {e}", opts.listen)))?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::Wire(format!("local_addr: {e}")))?;
    println!("{LISTENING_PREFIX}{addr}");
    std::io::stdout()
        .flush()
        .map_err(|e| Error::io("stdout", e))?;

    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("worker: accept: {e}");
                continue;
            }
        };
        match serve_conn(stream, &runtime, &manifest, opts) {
            Ok(true) => return Ok(()), // Shutdown frame
            Ok(false) => {}            // coordinator hung up; next session
            Err(e) => eprintln!("worker: connection error: {e}"),
        }
    }
    Ok(())
}

fn fresh_state(opts: &WorkerOptions) -> ConnState {
    ConnState {
        seed: 0,
        device: 0,
        // Until the handshake announces otherwise, assume the oldest
        // peer we speak — never send counters a v3 coordinator would
        // reject as trailing garbage.
        peer_proto: wire::MIN_PROTO_VERSION,
        tasks: HashMap::new(),
        failure: FailurePlan::None,
        net: opts.net.clone(),
        rate: opts.rate_macs_per_ms.filter(|r| r.is_finite() && *r > 0.0),
        counters: WorkerCounters::default(),
    }
}

/// Join mode: dial the coordinator's membership port, `Register` with
/// the announced compute rate, and serve the session at the device
/// slot assigned by `RegisterAck`. Returns when the coordinator closes
/// the connection (drain complete or session over).
fn run_joined(
    addr: &str,
    runtime: &Runtime,
    manifest: &Manifest,
    opts: &WorkerOptions,
) -> Result<()> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| Error::Wire(format!("join {addr}: {e}")))?;
    stream
        .set_nodelay(true)
        .map_err(|e| Error::Wire(format!("set_nodelay: {e}")))?;
    // 0.0 = "no announced rate": the coordinator falls back to its
    // configured per-device rate estimate.
    let announced = opts.rate_macs_per_ms.filter(|r| r.is_finite() && *r > 0.0);
    wire::write_frame(
        &mut stream,
        &wire::register(announced.unwrap_or(0.0), wire::CAP_COMPUTE),
    )?;
    let mut st = fresh_state(opts);
    match wire::read_frame(&mut stream)? {
        Some(Frame::RegisterAck { proto, device, seed }) if wire::proto_compatible(proto) => {
            st.seed = seed;
            st.device = device as usize;
            st.peer_proto = proto;
        }
        Some(Frame::RegisterAck { proto, .. }) => {
            return Err(wire::proto_mismatch("coordinator", "this worker", proto));
        }
        None => {
            return Err(Error::Wire(format!(
                "join {addr}: coordinator closed before RegisterAck \
                 (join rejected or fleet full)"
            )));
        }
        other => {
            return Err(Error::Wire(format!(
                "join {addr}: bad register reply: {other:?}"
            )));
        }
    }
    println!("cdc-dnn worker joined {addr} as device {}", st.device);
    let _ = std::io::stdout().flush();
    serve_frames(stream, runtime, manifest, &mut st, opts).map(|_| ())
}

/// Serve one coordinator connection; `Ok(true)` means a Shutdown frame
/// asked the whole process to exit.
fn serve_conn(
    stream: TcpStream,
    runtime: &Runtime,
    manifest: &Manifest,
    opts: &WorkerOptions,
) -> Result<bool> {
    stream
        .set_nodelay(true)
        .map_err(|e| Error::Wire(format!("set_nodelay: {e}")))?;
    let mut st = fresh_state(opts);
    serve_frames(stream, runtime, manifest, &mut st, opts)
}

/// The post-handshake frame loop shared by listen and join modes.
/// Writes go through a mutexed clone of the stream so the optional
/// `Leave` timer thread can inject its frame without interleaving
/// bytes into a half-written reply.
fn serve_frames(
    stream: TcpStream,
    runtime: &Runtime,
    manifest: &Manifest,
    st: &mut ConnState,
    opts: &WorkerOptions,
) -> Result<bool> {
    let mut rstream = stream
        .try_clone()
        .map_err(|e| Error::Wire(format!("clone stream: {e}")))?;
    let writer = Arc::new(Mutex::new(stream));
    if let Some(ms) = opts.leave_after_ms {
        let w = Arc::clone(&writer);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(ms));
            // Graceful drain announcement; in-flight orders keep being
            // served below until the coordinator closes the socket.
            let _ = wire::write_frame(&mut *lock(&w), &wire::leave());
        });
    }
    loop {
        let frame = match wire::read_frame(&mut rstream)? {
            Some(f) => f,
            None => return Ok(false), // coordinator closed the session
        };
        match frame {
            Frame::Hello { proto, seed, device } => {
                if !wire::proto_compatible(proto) {
                    return Err(wire::proto_mismatch("coordinator", "this worker", proto));
                }
                st.seed = seed;
                st.device = device as usize;
                st.peer_proto = proto;
                wire::write_frame(&mut *lock(&writer), &wire::hello_ack())?;
            }
            Frame::Heartbeat { nonce } => {
                // Proto ≥ 4 coordinators get the cumulative counter set
                // piggybacked on the ack; older peers get the bare v3
                // shape (the v4 decoder reads either).
                let ack = if st.peer_proto >= 4 {
                    wire::heartbeat_ack_with_counters(nonce, &st.counters.snapshot())
                } else {
                    wire::heartbeat_ack(nonce)
                };
                wire::write_frame(&mut *lock(&writer), &ack)?;
            }
            Frame::Deploy { tasks } => {
                for t in tasks {
                    let WireTask { id, artifact, macs, reply_bytes, w, quant, b } = t;
                    let weights = match (w, quant) {
                        (_, Some(q)) => TaskWeights::Int8 { quant: q },
                        (Some(w), None) => {
                            let packed = match w.shape() {
                                [m, k] if PackedWeights::pays_off(*m, *k) => {
                                    Some(PackedWeights::pack(w.data(), *m, *k))
                                }
                                _ => None,
                            };
                            TaskWeights::F32 { w, packed }
                        }
                        (None, None) => {
                            return Err(Error::Wire(format!(
                                "deployed task {id} carries no weights"
                            )));
                        }
                    };
                    st.tasks.insert(id, WorkerTask { artifact, macs, reply_bytes, weights, b });
                }
            }
            Frame::Undeploy { ids } => {
                for id in ids {
                    st.tasks.remove(&id);
                }
            }
            Frame::SetFailure { plan } => st.failure = plan,
            Frame::SetNet { enabled, net } => {
                st.net = enabled.then_some(net);
            }
            Frame::SetRate { macs_per_ms } => {
                st.rate = Some(macs_per_ms).filter(|r| r.is_finite() && *r > 0.0);
            }
            Frame::Shutdown => return Ok(true),
            Frame::Work { req, tasks, batch, input } => {
                work(&writer, runtime, manifest, st, req, tasks, batch, input)?;
            }
            other => {
                return Err(Error::Wire(format!(
                    "unexpected frame from coordinator: {other:?}"
                )));
            }
        }
    }
}

/// Execute one work order: real compute through the runtime, optional
/// emulated compute/network delay, reply per task — or silence when the
/// failure plan drops this order. Reply frames for the whole order are
/// coalesced into one buffer and hit the socket in a single
/// write+flush, mirroring the coordinator event loop's writev
/// coalescing on the other side of the wire.
#[allow(clippy::too_many_arguments)]
fn work(
    writer: &Mutex<TcpStream>,
    runtime: &Runtime,
    manifest: &Manifest,
    st: &mut ConnState,
    req: u64,
    tasks: Vec<u64>,
    batch: u32,
    input: Tensor,
) -> Result<()> {
    // Same content-addressed stream as the simulated device: the drop
    // decision and delay jitter replay identically across transports.
    let mut rng = Pcg32::new(
        st.seed,
        fleet::order_stream(st.device, tasks.first().copied(), batch as usize, &input),
    );
    let dropped = st.failure.drops(req, &mut rng);
    st.counters.orders += 1;
    let mut replies: Vec<u8> = Vec::new();
    for task_id in tasks {
        let result = match st.tasks.get(&task_id) {
            Some(t) => {
                let out = match &t.weights {
                    TaskWeights::F32 { w, packed } => runtime
                        .execute_prepared(
                            manifest,
                            &t.artifact,
                            &[w, &t.b, &input],
                            packed.as_ref(),
                            None,
                        )
                        .ok(),
                    TaskWeights::Int8 { quant } => runtime
                        .execute_prepared(manifest, &t.artifact, &[&t.b, &input], None, Some(quant))
                        .ok(),
                };
                if let Some(rate) = st.rate {
                    let ms = (batch as u64 * t.macs) as f64 / rate;
                    sleep_ms(ms);
                }
                if let Some(net) = &st.net {
                    sleep_ms(net.sample(batch as u64 * t.reply_bytes, &mut rng));
                }
                out
            }
            None => None, // unknown task: explicit failure reply below
        };
        if dropped && result.is_some() {
            // A "dropped" reply is silence — the coordinator's deadline
            // reaper is what notices, like a real lossy network.
            st.counters.dropped += 1;
            continue;
        }
        if result.is_none() {
            st.counters.exec_errors += 1;
        }
        st.counters.replies += 1;
        replies.extend_from_slice(&wire::reply(req, task_id, result.as_ref()));
    }
    if !replies.is_empty() {
        // Lock held for the write only — compute and emulated delays
        // above never block the Leave timer or a heartbeat ack.
        wire::write_frame(&mut *lock(writer), &replies)?;
    }
    Ok(())
}

fn sleep_ms(ms: f64) {
    if ms.is_finite() && ms > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(ms / 1e3));
    }
}
