//! Scenario-suite bench: runs the `exp::scenarios` driver (every named
//! fleet-chaos scenario × the three redundancy arms, on the synthetic
//! artifact set — no python/AOT build) and records the per-scenario
//! virtual-time serving quality — rps, p50, p99, loss/recovery counts —
//! to repo-root `BENCH_scenarios.json`, so the robustness trajectory is
//! tracked across PRs alongside `BENCH_gemm.json`. The suite loop itself
//! lives in `exp::scenarios::run` (single source of truth; the CLI's
//! `cdc-dnn scenarios` command runs the same code).
//!
//! `SCENARIO_BENCH_SMOKE=1` runs the driver in quick mode (scaled
//! horizons) for CI. The CDC no-lost-request invariant is enforced on
//! every run — the bench doubles as a regression guard.
//!
//! Run with `cargo bench --bench scenario_suite`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use cdc_dnn::exp::scenarios::{self, Arm};
use cdc_dnn::exp::ExpCtx;
use cdc_dnn::json::{obj, Value};

fn bench_out_path() -> PathBuf {
    // Benches run with cwd = the `rust` package; the baseline lives at
    // the repo root next to ROADMAP.md.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join("BENCH_scenarios.json"))
        .unwrap_or_else(|| PathBuf::from("BENCH_scenarios.json"))
}

fn main() {
    let smoke = std::env::var("SCENARIO_BENCH_SMOKE").is_ok();
    println!(
        "scenario_suite: compute backend = {}, smoke = {smoke}",
        cdc_dnn::runtime::backend_label()
    );

    let mut ctx = ExpCtx::new("artifacts");
    ctx.quick = smoke;
    let t0 = Instant::now();
    let points = scenarios::run(&ctx).expect("scenario suite");
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;

    let mut rows = Vec::new();
    let mut headline: Vec<(String, f64)> = Vec::new();
    let mode = if smoke { "smoke" } else { "full" };
    for p in &points {
        if p.arm.is_cdc() {
            assert_eq!(
                p.report.failed, 0,
                "{} arm lost requests in {}: {}",
                p.arm.label(),
                p.scenario,
                p.report.line()
            );
        }
        let s = p.report.latency.summary();
        rows.push(obj(vec![
            ("scenario", Value::Str(p.scenario.clone())),
            ("arm", Value::Str(p.arm.label().into())),
            ("completed", Value::Num(p.report.completed as f64)),
            ("failed", Value::Num(p.report.failed as f64)),
            ("recovered", Value::Num(p.report.recovered as f64)),
            ("rps", Value::Num(p.report.rps())),
            ("p50_ms", Value::Num(s.p50)),
            ("p99_ms", Value::Num(s.p99)),
            ("makespan_ms", Value::Num(p.report.makespan_ms)),
            ("rebuilds", Value::Num(p.report.rebuilds as f64)),
            ("max_batch", Value::Num(p.report.max_batch as f64)),
        ]));
        // CDC-arm rps per scenario is the robustness-throughput
        // trajectory the baseline guard tracks (virtual time:
        // deterministic in the seed, but horizon-scaled in smoke mode —
        // the keys carry the mode so seeds compare like-for-like).
        if p.arm.is_cdc() {
            headline.push((
                format!("{mode}_{}_{}_rps", p.scenario, p.arm.label()),
                p.report.rps(),
            ));
        }
    }

    let doc = obj(vec![
        ("experiment", Value::Str("bench_scenario_suite".into())),
        ("backend", Value::Str(cdc_dnn::runtime::backend_label().into())),
        ("smoke", Value::Bool(smoke)),
        ("suite_wall_ms", Value::Num(wall_ms)),
        ("scenarios", Value::Arr(rows)),
    ]);
    let out = bench_out_path();
    std::fs::write(&out, doc.to_string_pretty()).expect("write BENCH_scenarios.json");
    println!("[result] wrote {}", out.display());
    cdc_dnn::bench::guard_baseline("scenarios", &headline);
}
