//! Tiny property-testing substrate (offline environment: no proptest).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it reports the case index, the
//! reproducing seed, and a Debug dump of the failing input. Used by
//! `rust/tests/properties.rs` for the coordinator/CDC invariants.

use crate::rng::Pcg32;

/// Run `prop` over `cases` generated inputs; panics with a reproducible
/// seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        // Each case gets an independent, reconstructible stream.
        let mut rng = Pcg32::new(seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Pcg32;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// A vector of finite arrival times with `n_inf` entries set to ∞ at
    /// random positions — the canonical "arrivals with failures" input.
    pub fn arrivals(rng: &mut Pcg32, n: usize, n_inf: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1000.0)).collect();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        for &i in idx.iter().take(n_inf) {
            v[i] = f64::INFINITY;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            1,
            50,
            |rng| rng.below(100),
            |&x| if x < 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(2, 50, |rng| rng.below(10), |&x| {
            if x != 7 {
                Ok(())
            } else {
                Err("hit 7".into())
            }
        });
    }

    #[test]
    fn arrivals_have_requested_failures() {
        let mut rng = crate::rng::Pcg32::seeded(3);
        let a = gen::arrivals(&mut rng, 10, 3);
        assert_eq!(a.iter().filter(|t| t.is_infinite()).count(), 3);
    }
}
