//! Experiment drivers — one per table/figure of the paper's evaluation
//! plus the scenario suite (see DESIGN.md §4 for the index and
//! `docs/EXPERIMENTS.md` for the full experiment book). Each driver
//! prints its figure's rows/series to stdout and writes a
//! machine-readable JSON record under the results directory.

pub mod ablate;
pub mod calibrate;
pub mod case1;
pub mod case2;
pub mod fig1;
pub mod fig16;
pub mod fig17;
pub mod fig18;
pub mod fig2;
pub mod scenarios;
pub mod table1;

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::json::Value;

/// Shared experiment context (CLI-provided).
#[derive(Debug, Clone)]
pub struct ExpCtx {
    /// AOT artifacts directory.
    pub artifacts: PathBuf,
    /// Where result JSON files go.
    pub results: PathBuf,
    /// Request count per measured series (drivers may scale it).
    pub requests: usize,
    pub seed: u64,
    /// Reduced workload for smoke runs.
    pub quick: bool,
}

impl ExpCtx {
    /// Defaults rooted at the repo layout.
    pub fn new(artifacts: impl Into<PathBuf>) -> ExpCtx {
        ExpCtx {
            artifacts: artifacts.into(),
            results: PathBuf::from("results"),
            requests: 400,
            seed: 2021,
            quick: false,
        }
    }

    /// Effective request count (quick mode quarters it).
    pub fn n_requests(&self) -> usize {
        if self.quick {
            (self.requests / 4).max(20)
        } else {
            self.requests
        }
    }

    /// Write a result JSON document.
    pub fn write_result(&self, name: &str, v: &Value) -> Result<()> {
        std::fs::create_dir_all(&self.results)
            .map_err(|e| Error::io(self.results.display().to_string(), e))?;
        let path = self.results.join(format!("{name}.json"));
        std::fs::write(&path, v.to_string_pretty())
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        println!("[result] wrote {}", path.display());
        Ok(())
    }
}

/// Render a simple aligned table to stdout.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: Vec<String>| {
        let parts: Vec<String> = cells
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        println!("| {} |", parts.join(" | "));
    };
    line(headers.iter().map(|s| s.to_string()).collect());
    println!(
        "|{}|",
        widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    );
    for row in rows {
        line(row.clone());
    }
}
