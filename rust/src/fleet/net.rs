//! Stochastic WiFi latency model, fitted to the paper's Fig. 1.
//!
//! The paper measured, for a four-RPi system computing a 2048-wide fc layer
//! (50 ms of compute per shard), that only ~34% of responses arrive within
//! 100 ms and ~42% within 150 ms — i.e. the *network* delay distribution
//! has a fast mode (tens of ms) and a heavy congested tail. We model one
//! message's delay as
//!
//! ```text
//! delay = base_rtt/2 + bytes/bandwidth + mixture {
//!     P(fast):  LogNormal(mu, sigma)      — uncongested WLAN
//!     P(slow):  Pareto(x_m, alpha)        — contention/retransmit tail
//! }
//! ```
//!
//! and calibrate (see `tests::fig1_anchors`) so that the *response-time*
//! CDF of a 50 ms-compute shard reproduces the paper's anchors. The model
//! is seeded per device for reproducibility.

use crate::rng::Pcg32;

/// Parameters of the per-message delay distribution.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Client-to-client base latency (paper: 0.3 ms for 64 B).
    pub base_ms: f64,
    /// Link bandwidth in Mbit/s (paper: 94.1 Mbps measured).
    pub bandwidth_mbps: f64,
    /// Probability of the fast (uncongested) mode.
    pub p_fast: f64,
    /// Fast mode: lognormal location/scale (of ms).
    pub lognorm_mu: f64,
    pub lognorm_sigma: f64,
    /// Slow mode: Pareto scale (ms) and shape.
    pub pareto_xm: f64,
    pub pareto_alpha: f64,
    /// Hard cap on a single delay draw (ms) — a retransmitting WLAN
    /// eventually delivers or the transport times out.
    pub max_ms: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // Calibrated against Fig. 1 (see tests): P(net ≤ 50) ≈ 0.33,
        // P(net ≤ 100) ≈ 0.40, heavy tail to seconds.
        NetConfig {
            base_ms: 0.3,
            bandwidth_mbps: 94.1,
            p_fast: 0.34,
            lognorm_mu: 20.0f64.ln(),
            lognorm_sigma: 0.5,
            pareto_xm: 85.0,
            pareto_alpha: 1.1,
            max_ms: 10_000.0,
        }
    }
}

impl NetConfig {
    /// A moderately-loaded local WLAN: mostly-fast deliveries with an
    /// occasional congestion spike. Used by the case studies (Figs.
    /// 12-15), whose testbed is the paper's *measured* 0.3 ms-RTT local
    /// network; the default profile models Fig. 1's congested worst case
    /// and stays in use for Fig. 1/16.
    pub fn moderate() -> NetConfig {
        NetConfig {
            base_ms: 0.3,
            bandwidth_mbps: 94.1,
            p_fast: 0.85,
            lognorm_mu: 15.0f64.ln(),
            lognorm_sigma: 0.5,
            pareto_xm: 80.0,
            pareto_alpha: 1.6,
            max_ms: 3_000.0,
        }
    }

    /// The congested worst-case WLAN of Fig. 1 — an explicit name for
    /// [`NetConfig::default`], used by the scenario engine's regime-swap
    /// events (`ideal → moderate → congested`).
    pub fn congested() -> NetConfig {
        NetConfig::default()
    }

    /// An (unrealistically) ideal network — isolates compute effects in
    /// ablation benches.
    pub fn ideal() -> NetConfig {
        NetConfig {
            base_ms: 0.0,
            bandwidth_mbps: f64::INFINITY,
            p_fast: 1.0,
            lognorm_mu: f64::NEG_INFINITY, // exp → 0
            lognorm_sigma: 0.0,
            pareto_xm: 0.0,
            pareto_alpha: 1.0,
            max_ms: 0.0,
        }
    }

    /// Delay of the coordinator→device *request* leg (ms): base RTT +
    /// serialisation only. The congestion jitter is modelled on the reply
    /// leg (`sample`) where it is actually observed — all devices answer
    /// into the same contended uplink at once — which is also what makes
    /// the model calibratable against Fig. 1's single-response CDF.
    pub fn sample_request(&self, bytes: u64) -> f64 {
        let serialisation = if self.bandwidth_mbps.is_finite() {
            (bytes as f64 * 8.0) / (self.bandwidth_mbps * 1000.0)
        } else {
            0.0
        };
        (self.base_ms + serialisation).min(self.max_ms)
    }

    /// Sample one reply-leg delay (ms) for a payload of `bytes`.
    pub fn sample(&self, bytes: u64, rng: &mut Pcg32) -> f64 {
        let serialisation = if self.bandwidth_mbps.is_finite() {
            (bytes as f64 * 8.0) / (self.bandwidth_mbps * 1000.0)
        } else {
            0.0
        };
        let jitter = if rng.bernoulli(self.p_fast) {
            if self.lognorm_sigma == 0.0 {
                self.lognorm_mu.exp()
            } else {
                rng.lognormal(self.lognorm_mu, self.lognorm_sigma)
            }
        } else {
            rng.pareto(self.pareto_xm, self.pareto_alpha)
        };
        (self.base_ms + serialisation + jitter).min(self.max_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Series;

    /// The calibration test for Fig. 1: a shard with 50 ms compute and
    /// one request/response pair must land near the paper's CDF anchors.
    #[test]
    fn fig1_anchors() {
        let cfg = NetConfig::default();
        let mut rng = Pcg32::seeded(1);
        let mut s = Series::new();
        for _ in 0..40_000 {
            // response = request delay + 50 ms compute (responses carry
            // ~2 KiB of activations; request ~8 KiB of input).
            let t = cfg.sample(8 * 1024, &mut rng) + 50.0;
            s.record(t);
        }
        let c100 = s.cdf_at(100.0);
        let c150 = s.cdf_at(150.0);
        assert!(s.summary().min >= 50.0, "nothing beats compute time");
        assert!((c100 - 0.34).abs() < 0.08, "CDF(100ms)={c100}");
        assert!((c150 - 0.42).abs() < 0.08, "CDF(150ms)={c150}");
        // Heavy tail: p99 well beyond 2× compute.
        assert!(s.summary().p99 > 500.0, "p99={}", s.summary().p99);
    }

    #[test]
    fn ideal_network_is_deterministic_zero() {
        let cfg = NetConfig::ideal();
        let mut rng = Pcg32::seeded(2);
        for _ in 0..100 {
            assert_eq!(cfg.sample(1 << 20, &mut rng), 0.0);
        }
    }

    /// Empirical CDF of `n` reply-leg draws at a grid of horizons.
    fn cdf_grid(cfg: &NetConfig, seed: u64, n: usize, grid: &[f64]) -> Vec<f64> {
        let mut rng = Pcg32::seeded(seed);
        let mut s = Series::new();
        for _ in 0..n {
            s.record(cfg.sample(2 * 1024, &mut rng));
        }
        grid.iter().map(|&x| s.cdf_at(x)).collect()
    }

    /// Property: the profile ladder is stochastically ordered — at every
    /// horizon, `ideal` delivers at least as often as `moderate`, which
    /// delivers at least as often as the congested `default`. (The lighter
    /// profiles are *dominated* by default's delay distribution.)
    #[test]
    fn profile_ladder_is_stochastically_ordered() {
        let grid = [1.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 3000.0];
        let n = 20_000;
        let congested = cdf_grid(&NetConfig::congested(), 21, n, &grid);
        let moderate = cdf_grid(&NetConfig::moderate(), 22, n, &grid);
        let ideal = cdf_grid(&NetConfig::ideal(), 23, n, &grid);
        for (i, &x) in grid.iter().enumerate() {
            assert!(
                moderate[i] + 0.02 >= congested[i],
                "moderate CDF({x}ms)={} < default {}",
                moderate[i],
                congested[i]
            );
            assert!(
                ideal[i] + 1e-12 >= moderate[i],
                "ideal CDF({x}ms)={} < moderate {}",
                ideal[i],
                moderate[i]
            );
        }
        // ideal is degenerate at 0 — dominated by everything, dominating
        // nothing.
        assert!(ideal.iter().all(|&c| c == 1.0));
    }

    /// Property: `max_ms` caps every draw, across random configurations
    /// with deliberately heavy tails and random payloads.
    #[test]
    fn max_ms_caps_every_draw() {
        crate::testkit::forall(
            31,
            200,
            |rng| {
                let mut cfg = NetConfig::default();
                cfg.p_fast = rng.f64();
                cfg.lognorm_mu = rng.range(0.0, 8.0); // e^8 ≈ 3 s jitter
                cfg.lognorm_sigma = rng.range(0.0, 2.0);
                cfg.pareto_xm = rng.range(1.0, 500.0);
                cfg.pareto_alpha = rng.range(0.8, 2.0);
                cfg.max_ms = rng.range(0.5, 50.0);
                let bytes = rng.below(1 << 22) as u64;
                (cfg, bytes, rng.next_u64())
            },
            |(cfg, bytes, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                for _ in 0..100 {
                    let d = cfg.sample(*bytes, &mut rng);
                    if d > cfg.max_ms {
                        return Err(format!("draw {d} exceeds max_ms {}", cfg.max_ms));
                    }
                    let r = cfg.sample_request(*bytes);
                    if r > cfg.max_ms {
                        return Err(format!("request leg {r} exceeds max_ms {}", cfg.max_ms));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bandwidth_term_scales_with_bytes() {
        let mut cfg = NetConfig::default();
        cfg.p_fast = 1.0;
        cfg.lognorm_sigma = 0.0;
        cfg.lognorm_mu = 0.0; // jitter = 1 ms constant
        let mut rng = Pcg32::seeded(3);
        let small = cfg.sample(0, &mut rng);
        let big = cfg.sample(94_100_000 / 8, &mut rng); // exactly 1 s of payload
        assert!((big - small - 1000.0).abs() < 1e-6, "{big} vs {small}");
    }
}
