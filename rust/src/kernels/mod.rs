//! High-performance compute kernel layer (DESIGN.md §8, §15).
//!
//! The paper's CDC overhead claims are all *ratios against a GEMM*: the
//! parity encode, the recovery subtraction, and the straggler gate only
//! read as "close to zero" when the underlying matrix multiply is as
//! fast as the host allows. This module is that baseline: a cache-blocked,
//! register-tiled f32 [`gemm`] with a scoped-thread row driver and
//! runtime-dispatched explicit-SIMD micro-kernels ([`simd`]: AVX2 /
//! NEON, falling back to the scalar tile), deploy-time packed-weight
//! caching ([`pack`]), an int8-quantized GEMM with a computable error
//! bound ([`qgemm`]), the shared epilogues (bias/ReLU and the fused CDC
//! parity checksum), and the [`Scratch`] buffer arena that makes the
//! steady-state serving compute path allocation-free. The interpreter
//! backend (`runtime::interp`), `Tensor::matmul`, and the coordinator's
//! merge path are all lowered onto it; later PJRT backends plug in at
//! the same seam.

pub mod gemm;
pub mod pack;
pub mod qgemm;
pub mod scratch;
pub mod simd;

pub use gemm::{
    auto_threads, bias_relu, gemm_auto, gemm_naive, gemm_simd, gemm_threaded,
    gemm_threaded_with, gemm_tiled, gemm_tiled_with, row_block_checksum, KC, MC, MR, NC, NR,
};
pub use pack::{gemm_prepacked, gemm_prepacked_auto, gemm_prepacked_threaded, PackedWeights};
pub use qgemm::{error_bound, qgemm, quantize_activation, Precision, QuantWeights, QBLOCK_ROWS};
pub use scratch::{with_scratch, Scratch};
pub use simd::{active_tier, simd_available, tier_supported, Tier};
