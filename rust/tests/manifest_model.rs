//! Integration tests over the manifest/model/config layers against the
//! real artifact set: error paths, weight loading, cost model, and the
//! local pipeline's accuracy on the trained model.

use cdc_dnn::config::{deployment_from_json, load_deployment};
use cdc_dnn::json::Value;
use cdc_dnn::model::{layer_macs, load_eval_set, shard_io_bytes, shard_macs, LocalPipeline, Weights};
use cdc_dnn::partition::LayerPlan;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::{Manifest, Runtime};

fn artifacts_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Artifact-dependent tests skip (with a note) instead of failing — the
/// synthetic-manifest tests in `serve_pipeline.rs` cover the coordinator
/// stack without the python build.
fn have_artifacts() -> bool {
    cdc_dnn::testkit::artifacts_available(&artifacts_root())
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            return;
        }
    };
}

#[test]
fn manifest_rejects_missing_dir() {
    assert!(Manifest::load("/nonexistent/path").is_err());
}

#[test]
fn manifest_unknown_lookups_error_helpfully() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    let err = format!("{}", m.model("nope").unwrap_err());
    assert!(err.contains("nope"));
    let err = format!("{}", m.artifact("nope").unwrap_err());
    assert!(err.contains("nope"));
}

#[test]
fn all_models_load_weights_with_consistent_shapes() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    for model in m.models.values() {
        let w = Weights::load(&m, model).unwrap();
        for layer in &model.layers {
            if !layer.is_weighted() {
                continue;
            }
            let (mm, kk) = layer.w_shape.unwrap();
            assert_eq!(w.w(&layer.name).unwrap().shape(), &[mm, kk]);
            assert_eq!(w.b(&layer.name).unwrap().shape(), &[mm, 1]);
        }
    }
}

#[test]
fn cost_model_is_monotone_in_split_degree() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    let model = m.model("fc2048").unwrap();
    let layer = &model.layers[0];
    let total = layer_macs(layer);
    assert_eq!(total, 2048 * 2048);
    let mut prev = u64::MAX;
    for d in [1usize, 2, 4, 8] {
        let s = shard_macs(layer, d);
        assert!(s <= prev, "shard macs must shrink with d");
        assert!(s * d as u64 >= total, "shards must cover the layer");
        prev = s;
    }
    let (req, reply) = shard_io_bytes(layer, 4);
    assert_eq!(req, 2048 * 4);
    assert_eq!(reply, 512 * 4);
}

#[test]
fn layer_plan_rejects_missing_split_degree() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    let model = m.model("fc2048").unwrap();
    let err = LayerPlan::build(&model.layers[0], 5).unwrap_err();
    assert!(format!("{err}").contains("split degree 5"));
}

#[test]
fn layer_plan_covers_all_rows() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    let model = m.model("lenet5").unwrap();
    for layer in model.layers.iter().filter(|l| l.is_weighted()) {
        for &d in layer.splits.keys() {
            let plan = LayerPlan::build(layer, d).unwrap();
            let total = if layer.kind == "fc" { layer.m } else { layer.k };
            assert_eq!(plan.covered_rows(), total, "{}@{d}", layer.name);
        }
    }
}

#[test]
fn trained_lenet_accuracy_through_artifacts() {
    require_artifacts!();
    // The local pipeline (d=1 artifacts, rust epilogues) must reproduce
    // the training-time accuracy — the Fig. 2 zero-loss anchor.
    let m = Manifest::load(artifacts_root()).unwrap();
    let rt = Runtime::new().unwrap();
    let model = m.model("lenet5").unwrap();
    let weights = Weights::load(&m, model).unwrap();
    let pipe = LocalPipeline { runtime: &rt, manifest: &m, model, weights: &weights };
    let (images, labels) = load_eval_set(&m).unwrap();
    let n = 64.min(images.len());
    let mut rng = Pcg32::seeded(0);
    let acc = pipe.accuracy(&images[..n], &labels[..n], None, &mut rng).unwrap();
    assert!(acc > 0.9, "trained model accuracy through rust pipeline: {acc}");
}

#[test]
fn deployment_file_round_trips_through_disk() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/lenet5_cdc.json");
    let cfg = load_deployment(&path).unwrap();
    assert_eq!(cfg.model, "lenet5");
    assert_eq!(cfg.n_devices, 4);
    assert_eq!(cfg.splits["fc1"].d, 4);
    assert_eq!(cfg.placement["fc1"], vec![0, 1, 2, 3]);
}

#[test]
fn deployment_rejects_malformed_specs() {
    let bad = Value::parse(r#"{"model":"lenet5"}"#).unwrap();
    assert!(deployment_from_json(&bad).is_err(), "n_devices required");
    let bad = Value::parse(
        r#"{"model":"lenet5","n_devices":2,"splits":{"fc1":{"d":2,"redundancy":"xyz"}}}"#,
    )
    .unwrap();
    assert!(deployment_from_json(&bad).is_err(), "bad redundancy tag");
}

#[test]
fn eval_set_matches_manifest_count() {
    require_artifacts!();
    let m = Manifest::load(artifacts_root()).unwrap();
    let (images, labels) = load_eval_set(&m).unwrap();
    assert_eq!(images.len(), m.eval_set.count);
    assert_eq!(labels.len(), m.eval_set.count);
    assert!(labels.iter().all(|&l| (0..10).contains(&l)));
}
