//! Scenario suite — scripted fleet-chaos runs over the serving pipeline
//! (DESIGN.md §9, ROADMAP "handles as many scenarios as you can
//! imagine").
//!
//! Seven named scenarios cover the paper's §2 failure taxonomy as
//! *time-varying* regimes: `steady` (control), `crash-storm` (staggered
//! permanent failures + an intermittent phase), `churn` (devices
//! leave/join with re-partitioning), `congested-wlan` (Fig. 1's WLAN
//! regime sweeping in and out), `hetero-fleet` (RPi3/RPi4-style rate
//! mixes that turn devices into persistent stragglers), `burst`
//! (arrival spikes on top of the Poisson stream), and `churn-kill` (a
//! worker SIGKILLed while another is mid-join — the live-membership
//! stress, DESIGN.md §13). Every scenario runs across four redundancy
//! **arms** — no redundancy, replication (2MR), parity-coded CDC with
//! the adaptive policy, and CDC with cross-request micro-batching
//! (`cdc-b4`, DESIGN.md §10) — and the driver records per-arm
//! rps/p50/p99 to `results/scenarios.json`.
//!
//! [`run_tcp`] replays the same catalog over a **real loopback worker
//! fleet** on the wall clock (`scenarios --transport tcp`): kills are
//! SIGKILLs, joins are live `Register` handshakes, and every joiner
//! announces a graceful `Leave` before the horizon — the zero-loss
//! churn acceptance gate.
//!
//! The suite deploys the synthetic `testkit::synth` model, so — unlike
//! the figure reproductions — it needs no AOT artifact build: it
//! measures the serving engine, the recovery machinery, and the adaptive
//! policy, not XLA. The paper-invariant ("coded serving never loses a
//! request, p99 degrades gracefully") is asserted for every scenario by
//! `rust/tests/scenario_engine.rs` and re-checked by
//! `benches/scenario_suite.rs`.

use crate::coordinator::{
    AdaptiveConfig, Redundancy, Session, SessionConfig, SplitSpec, Workload,
};
use crate::error::{Error, Result};
use crate::fleet::{FailurePlan, NetConfig};
use crate::json::{obj, Value};
use crate::rng::Pcg32;
use crate::runtime::manifest::Manifest;
use crate::scenario::{
    Action, NetProfile, Scenario, ScenarioEngine, ScenarioReport, SegmentReport,
};
use crate::tensor::Tensor;
use crate::testkit::synth;
use crate::transport::{loopback::LoopbackFleet, TransportSpec};

use std::path::Path;
use std::sync::{Arc, Mutex};

use super::{print_table, ExpCtx};

/// A redundancy arm of the suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arm {
    /// No redundancy: a failed shard loses the request.
    None,
    /// Replication (2MR): every shard duplicated.
    Replication,
    /// Parity-coded CDC with the adaptive policy on.
    Cdc,
    /// CDC + cross-request micro-batching (`batch_max` =
    /// [`BATCHED_ARM_WIDTH`], DESIGN.md §10): the paper invariant must
    /// survive a device failure killing a whole batch.
    CdcBatched,
}

/// Micro-batch width of the [`Arm::CdcBatched`] arm.
pub const BATCHED_ARM_WIDTH: usize = 4;
/// Batch-formation window (virtual ms) of the [`Arm::CdcBatched`] arm.
pub const BATCHED_ARM_WAIT_MS: f64 = 4.0;

impl Arm {
    /// All arms, table order.
    pub const ALL: [Arm; 4] = [Arm::None, Arm::Replication, Arm::Cdc, Arm::CdcBatched];

    /// Tag used in tables and JSON.
    pub fn label(self) -> &'static str {
        match self {
            Arm::None => "none",
            Arm::Replication => "2mr",
            Arm::Cdc => "cdc",
            Arm::CdcBatched => "cdc-b4",
        }
    }

    /// Arms that run parity-coded CDC — the no-lost-request invariant
    /// applies to these.
    pub fn is_cdc(self) -> bool {
        matches!(self, Arm::Cdc | Arm::CdcBatched)
    }

    fn redundancy(self) -> Redundancy {
        match self {
            Arm::None => Redundancy::None,
            Arm::Replication => Redundancy::TwoMr,
            Arm::Cdc | Arm::CdcBatched => Redundancy::Cdc,
        }
    }
}

/// The deployment template one (scenario, arm) pair runs on: the
/// synthetic MLP, fc1 target-split 4 ways and fc2 2 ways over four data
/// devices, redundancy per the arm, a fast failure-detection window (the
/// chaos scripts flip failures every few hundred virtual ms), the
/// adaptive policy on the CDC arms, and micro-batching on `cdc-b4`.
pub fn arm_cfg(sc: &Scenario, arm: Arm) -> SessionConfig {
    let mut cfg = SessionConfig::new(synth::MODEL);
    cfg.n_devices = 4;
    cfg.seed = sc.seed;
    cfg.net = sc.initial_net.config();
    if let Some(r) = sc.device_rate {
        cfg.device_rate = r;
    }
    cfg.detection_ms = 250.0;
    cfg.threshold_factor = 2.0;
    cfg.splits
        .insert("fc1".into(), SplitSpec { d: 4, redundancy: arm.redundancy() });
    cfg.splits
        .insert("fc2".into(), SplitSpec { d: 2, redundancy: arm.redundancy() });
    if arm.is_cdc() {
        cfg.adaptive = Some(AdaptiveConfig::default());
    }
    if arm == Arm::CdcBatched {
        cfg.batch_max = BATCHED_ARM_WIDTH;
        cfg.batch_wait_ms = BATCHED_ARM_WAIT_MS;
    }
    cfg
}

/// Control run: no events, moderate WLAN.
pub fn steady(seed: u64) -> Scenario {
    Scenario::new("steady", 800.0, 50.0, seed)
}

/// Staggered permanent failures with recovery windows, then an
/// intermittent (flaky-reply) phase. At most one fc1 device is unhealthy
/// at a time — the single-parity tolerance the paper's scheme promises
/// to mask.
pub fn crash_storm(seed: u64) -> Scenario {
    Scenario::new("crash-storm", 1000.0, 50.0, seed)
        .at(200.0, Action::Crash { device: 2 })
        .at(400.0, Action::Recover { device: 2 })
        .at(450.0, Action::Crash { device: 3 })
        .at(650.0, Action::Recover { device: 3 })
        .at(700.0, Action::Flaky { device: 1, p: 0.3 })
        .at(900.0, Action::Recover { device: 1 })
}

/// Fleet churn: two devices leave (splits re-partition 4 → 2 via the
/// partition planner), then rejoin (back to 4).
pub fn churn(seed: u64) -> Scenario {
    Scenario::new("churn", 900.0, 40.0, seed)
        .at(300.0, Action::Leave { n: 2 })
        .at(600.0, Action::Join { n: 2 })
}

/// WLAN regime sweep: the Fig.-1 congested profile rolls in over a
/// moderate network and clears again.
pub fn congested_wlan(seed: u64) -> Scenario {
    Scenario::new("congested-wlan", 900.0, 40.0, seed)
        .at(250.0, Action::Net { profile: NetProfile::Congested })
        .at(600.0, Action::Net { profile: NetProfile::Moderate })
}

/// Heterogeneous fleet on an ideal network with compute slowed so rate
/// differences dominate: one device drops to 0.4×, later another to
/// 0.25× — persistent stragglers the gate + parity substitution absorb.
pub fn hetero_fleet(seed: u64) -> Scenario {
    Scenario::new("hetero-fleet", 800.0, 40.0, seed)
        .with_net(NetProfile::Ideal)
        .with_device_rate(3.0) // fc1 shard ≈ 20 ms: compute dominates
        .at(1.0, Action::Slowdown { device: 1, factor: 0.4 })
        .at(400.0, Action::Slowdown { device: 3, factor: 0.25 })
}

/// Arrival-spike scenario: two 25-request bursts on a 30 rps base
/// stream, plus a rate step in between.
pub fn burst(seed: u64) -> Scenario {
    Scenario::new("burst", 900.0, 30.0, seed)
        .at(300.0, Action::Burst { n: 25 })
        .at(450.0, Action::Rate { rps: 60.0 })
        .at(600.0, Action::Burst { n: 25 })
        .at(650.0, Action::Rate { rps: 30.0 })
}

/// Live-membership stress: a fresh device joins, an original worker is
/// SIGKILLed 50 ms later (while the joiner may still be registering),
/// and a second device joins after the fleet has re-partitioned around
/// the death. On the simulator `Kill` degrades to a permanent crash;
/// over TCP ([`run_tcp`]) it is a literal SIGKILL and the joins are live
/// `Register` handshakes (DESIGN.md §13).
pub fn churn_kill(seed: u64) -> Scenario {
    Scenario::new("churn-kill", 1000.0, 40.0, seed)
        .at(250.0, Action::Join { n: 1 })
        .at(300.0, Action::Kill { device: 1 })
        .at(550.0, Action::Join { n: 1 })
}

/// Every named scenario, suite order.
pub fn catalog(seed: u64) -> Vec<Scenario> {
    vec![
        steady(seed),
        crash_storm(seed),
        churn(seed),
        congested_wlan(seed),
        hetero_fleet(seed),
        burst(seed),
        churn_kill(seed),
    ]
}

/// One (scenario, arm) measurement.
#[derive(Debug)]
pub struct SuitePoint {
    /// Scenario name.
    pub scenario: String,
    /// Redundancy arm.
    pub arm: Arm,
    /// The merged scenario report.
    pub report: ScenarioReport,
}

/// Run the full suite; prints the per-arm table, writes
/// `results/scenarios.json`, and returns the points for tests.
pub fn run(ctx: &ExpCtx) -> Result<Vec<SuitePoint>> {
    let arts = synth::build(ctx.seed)?;
    let scale = if ctx.quick { 0.5 } else { 1.0 };
    let mut points = Vec::new();
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();

    println!("\n=== Scenario suite (synthetic model, virtual time) ===");
    for sc in catalog(ctx.seed) {
        let sc = sc.scaled(scale);
        for arm in Arm::ALL {
            let mut engine = ScenarioEngine::new(&arts.root, arm_cfg(&sc, arm))?;
            let report = engine.run(&sc)?;
            let s = report.latency.summary();
            rows.push(vec![
                sc.name.clone(),
                arm.label().into(),
                format!("{}", report.completed),
                format!("{}", report.failed),
                format!("{}", report.recovered),
                format!("{:.1}", report.rps()),
                format!("{:.1}", s.p50),
                format!("{:.1}", s.p99),
            ]);
            let mut fields = vec![
                ("scenario", Value::Str(sc.name.clone())),
                ("arm", Value::Str(arm.label().into())),
                ("completed", Value::Num(report.completed as f64)),
                ("failed", Value::Num(report.failed as f64)),
                ("recovered", Value::Num(report.recovered as f64)),
                ("dropped", Value::Num(report.dropped as f64)),
                ("rps", Value::Num(report.rps())),
                ("p50_ms", Value::Num(s.p50)),
                ("p99_ms", Value::Num(s.p99)),
                ("makespan_ms", Value::Num(report.makespan_ms)),
                ("rebuilds", Value::Num(report.rebuilds as f64)),
                ("max_batch", Value::Num(report.max_batch as f64)),
            ];
            if let Some(p) = &report.policy {
                fields.push((
                    "policy",
                    obj(vec![
                        ("threshold_factor", Value::Num(p.threshold_factor)),
                        ("drop_rate", Value::Num(p.drop_rate)),
                        ("stragglers", Value::Num(p.stragglers as f64)),
                        (
                            "recommended",
                            Value::Str(
                                match p.recommended {
                                    Redundancy::TwoMr => "2mr",
                                    _ => "cdc",
                                }
                                .into(),
                            ),
                        ),
                    ]),
                ));
            }
            json_rows.push(obj(fields));
            points.push(SuitePoint { scenario: sc.name.clone(), arm, report });
        }
    }

    print_table(
        &["scenario", "arm", "served", "lost", "recovered", "rps", "p50 ms", "p99 ms"],
        &rows,
    );
    println!(
        "(CDC arm: adaptive straggler gate + parity substitution — the\n\
         no-lost-request invariant across every scenario is asserted by\n\
         `cargo test -q scenario`)"
    );

    ctx.write_result(
        "scenarios",
        &obj(vec![
            ("experiment", Value::Str("scenario_suite".into())),
            ("backend", Value::Str(crate::runtime::backend_label().into())),
            ("scale", Value::Num(scale)),
            ("points", Value::Arr(json_rows)),
        ]),
    )?;
    Ok(points)
}

// ---------------------------------------------------------------------
// The TCP replay: same catalog, real processes, wall clock.
// ---------------------------------------------------------------------

/// Wall-clock order deadline (ms) for the TCP suite — on real time the
/// deadline *is* the straggler/failure gate: replies later than this are
/// treated as lost and reconstructed from parity.
const TCP_ORDER_DEADLINE_MS: f64 = 250.0;

/// Cap (ms) on the worker-emulated WLAN reply delay during `Net` regime
/// events over TCP. The congested profile's Pareto tail reaches seconds;
/// capped below the order deadline it stresses latency without being
/// able to produce the ≥ 2 simultaneous in-group losses that would break
/// the zero-loss invariant by construction rather than by fault.
const TCP_NET_CAP_MS: f64 = 120.0;

/// A process-level chaos action, fired by a timer thread at its
/// scheduled wall-clock instant while the coordinator serves.
enum TcpAct {
    /// SIGKILL worker `i` (connection death → membership `Dead`).
    Kill(usize),
    /// Spawn a `worker --join` that registers against the live
    /// coordinator; with `leave_after_ms` set it announces a graceful
    /// `Leave` that long after joining (the drain path).
    Join { leave_after_ms: Option<u64> },
}

/// A session-level regime change. These need `&mut Session`, so they
/// apply *between* serve segments — the same quiescent event ordering
/// the simulator engine uses.
enum TcpBoundary {
    Failure(usize, FailurePlan),
    Net(NetConfig),
    DeviceRate(usize, f64),
    Rate(f64),
    Burst(usize),
}

/// Compile a scenario script into its TCP execution plan: absolute-time
/// process chaos (timer threads) plus ordered serve-segment boundaries.
///
/// Mapping rules, by what real processes can actually do:
/// * `Crash`/`Kill` → SIGKILL the worker. A killed process cannot come
///   back, so a later `Recover` of that device spawns a *fresh* joiner
///   instead (device slots are never reused).
/// * `Leave { n }` → SIGKILL the `n` highest-indexed surviving original
///   workers (devices vanishing); graceful `Leave` drains are exercised
///   by the joiners, each of which announces one before the horizon.
/// * `Flaky`/`Recover`-of-healthy/`Net`/`Slowdown` → segment boundaries
///   (worker-side emulation via the control frames).
/// * `Rate`/`Burst` → arrival-schedule boundaries, as in the simulator.
fn tcp_plan(
    sc: &Scenario,
    n_workers: usize,
    base_device_rate: f64,
) -> (Vec<(f64, TcpAct)>, Vec<(f64, TcpBoundary)>) {
    let mut order: Vec<usize> = (0..sc.events.len()).collect();
    order.sort_by(|&a, &b| {
        sc.events[a]
            .at_ms
            .partial_cmp(&sc.events[b].at_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut killed: Vec<usize> = Vec::new();
    let mut timers = Vec::new();
    let mut bounds = Vec::new();
    for &ei in &order {
        let ev = &sc.events[ei];
        let t = ev.at_ms.clamp(0.0, sc.duration_ms);
        match &ev.action {
            Action::Crash { device } | Action::Kill { device } => {
                if *device < n_workers && !killed.contains(device) {
                    killed.push(*device);
                    timers.push((t, TcpAct::Kill(*device)));
                }
            }
            Action::Recover { device } => {
                if killed.contains(device) {
                    timers.push((t, TcpAct::Join { leave_after_ms: None }));
                } else {
                    bounds.push((t, TcpBoundary::Failure(*device, FailurePlan::None)));
                }
            }
            Action::Flaky { device, p } => {
                bounds.push((
                    t,
                    TcpBoundary::Failure(*device, FailurePlan::Intermittent(*p)),
                ));
            }
            Action::Join { n } => {
                let leave = ((sc.duration_ms - t) * 0.6).max(50.0) as u64;
                for _ in 0..*n {
                    timers.push((t, TcpAct::Join { leave_after_ms: Some(leave) }));
                }
            }
            Action::Leave { n } => {
                let mut shed = 0usize;
                for d in (0..n_workers).rev() {
                    if shed == *n {
                        break;
                    }
                    if !killed.contains(&d) {
                        killed.push(d);
                        timers.push((t, TcpAct::Kill(d)));
                        shed += 1;
                    }
                }
            }
            Action::Net { profile } => {
                let mut net = profile.config();
                net.max_ms = net.max_ms.min(TCP_NET_CAP_MS);
                bounds.push((t, TcpBoundary::Net(net)));
            }
            Action::Slowdown { device, factor } => {
                bounds.push((
                    t,
                    TcpBoundary::DeviceRate(*device, base_device_rate * factor),
                ));
            }
            Action::Rate { rps } => bounds.push((t, TcpBoundary::Rate(*rps))),
            Action::Burst { n } => bounds.push((t, TcpBoundary::Burst(*n))),
        }
    }
    (timers, bounds)
}

/// Serve one inter-boundary segment on the wall clock: a Poisson stream
/// at the current rate over `span` ms (plus any pending burst at the
/// segment start), merged into the accumulating report.
#[allow(clippy::too_many_arguments)]
fn serve_tcp_segment(
    session: &mut Session,
    report: &mut ScenarioReport,
    rng: &mut Pcg32,
    input_shape: &[usize],
    t0: f64,
    span: f64,
    rate_rps: f64,
    burst: usize,
    event: Option<String>,
) -> Result<()> {
    let span = span.max(0.0);
    let mut at: Vec<f64> = vec![0.0; burst];
    if rate_rps > 0.0 && span > 0.0 {
        let per_ms = rate_rps / 1000.0;
        let mut t = rng.exponential(per_ms);
        while t < span {
            at.push(t);
            t += rng.exponential(per_ms);
        }
    }
    at.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let arrivals = at.len();
    let mut seg = SegmentReport {
        t_start_ms: t0,
        arrivals,
        completed: 0,
        failed: 0,
        recovered: 0,
        dropped: 0,
        p99_ms: 0.0,
        event,
    };
    if arrivals > 0 {
        let inputs: Vec<Tensor> = (0..arrivals)
            .map(|_| Tensor::randn(input_shape.to_vec(), rng))
            .collect();
        let r = session.serve(&Workload::explicit(inputs, at))?;
        seg.completed = r.throughput.completed;
        seg.failed = r.throughput.failed;
        seg.recovered = r.throughput.recovered;
        seg.dropped = r.dropped;
        seg.p99_ms = r.latency.summary().p99;
        report.completed += r.throughput.completed;
        report.failed += r.throughput.failed;
        report.recovered += r.throughput.recovered;
        report.dropped += r.dropped;
        for &s in r.latency.samples() {
            report.latency.record(s);
        }
        report.max_batch = report.max_batch.max(r.max_batch);
        // Wall-clock segments run back to back: the suite makespan is
        // their serialized span.
        report.makespan_ms += r.makespan_ms;
    }
    report.segments.push(seg);
    Ok(())
}

/// Run one scenario's CDC arm over a freshly spawned loopback fleet.
fn run_tcp_scenario(root: &Path, sc: &Scenario) -> Result<ScenarioReport> {
    let mut cfg = arm_cfg(sc, Arm::Cdc);
    // The loopback link IS the network: coordinator estimates start
    // ideal, and `Net` regime events emulate delay on the workers.
    cfg.net = NetConfig::ideal();
    let n0 = cfg.planned_devices();
    let fleet = LoopbackFleet::spawn(None, root, n0, sc.device_rate)?;
    let mut tcp = fleet.tcp_config();
    tcp.order_deadline_ms = TCP_ORDER_DEADLINE_MS;
    cfg.transport = TransportSpec::Tcp(tcp);
    let base_device_rate = cfg.device_rate;

    let manifest = Manifest::load(root)?;
    let input_shape = manifest.model(&cfg.model)?.input_shape.clone();
    let mut session = Session::start(root, cfg)?;
    let addr = session.membership_addr().ok_or_else(|| {
        Error::Config(
            "tcp scenario suite needs the membership listener (TcpConfig::listen)".into(),
        )
    })?;

    let (timers, bounds) = tcp_plan(sc, n0, base_device_rate);
    let fleet = Arc::new(Mutex::new(fleet));
    let mut handles = Vec::new();
    for (t, act) in timers {
        let fleet = Arc::clone(&fleet);
        let root = root.to_path_buf();
        let addr = addr.clone();
        let rate = sc.device_rate;
        handles.push(std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(t as u64));
            let mut f = fleet.lock().unwrap_or_else(|e| e.into_inner());
            let r = match act {
                TcpAct::Kill(d) => f.kill(d),
                TcpAct::Join { leave_after_ms } => f
                    .spawn_joiner(None, &root, &addr, rate, leave_after_ms)
                    .map(|_| ()),
            };
            if let Err(e) = r {
                eprintln!("scenario chaos action failed: {e}");
            }
        }));
    }

    let mut report = ScenarioReport {
        scenario: sc.name.clone(),
        completed: 0,
        failed: 0,
        recovered: 0,
        dropped: 0,
        latency: crate::metrics::Series::new(),
        makespan_ms: 0.0,
        segments: Vec::new(),
        rebuilds: 0,
        max_batch: 1,
        policy: None,
    };
    let mut rng = Pcg32::new(sc.seed, 0x7c9);
    let mut rate = sc.base_rate_rps;
    let mut burst = 0usize;
    let mut t0 = 0.0f64;
    for (t1, b) in bounds {
        let label = match &b {
            TcpBoundary::Failure(d, FailurePlan::None) => format!("recover(d{d})"),
            TcpBoundary::Failure(d, _) => format!("flaky(d{d})"),
            TcpBoundary::Net(_) => "net".to_string(),
            TcpBoundary::DeviceRate(d, r) => format!("rate(d{d},{r:.2})"),
            TcpBoundary::Rate(rps) => format!("rate({rps}rps)"),
            TcpBoundary::Burst(n) => format!("burst({n})"),
        };
        serve_tcp_segment(
            &mut session,
            &mut report,
            &mut rng,
            &input_shape,
            t0,
            t1 - t0,
            rate,
            std::mem::take(&mut burst),
            Some(label),
        )?;
        match b {
            TcpBoundary::Failure(d, plan) => session.set_failure(d, plan)?,
            TcpBoundary::Net(net) => session.set_net(net)?,
            TcpBoundary::DeviceRate(d, r) => session.set_device_rate(d, r)?,
            TcpBoundary::Rate(rps) => rate = rps,
            TcpBoundary::Burst(n) => burst += n,
        }
        t0 = t1;
    }
    serve_tcp_segment(
        &mut session,
        &mut report,
        &mut rng,
        &input_shape,
        t0,
        sc.duration_ms - t0,
        rate,
        std::mem::take(&mut burst),
        None,
    )?;
    for h in handles {
        let _ = h.join();
    }
    report.policy = session.policy_snapshot();
    // Over TCP a "rebuild" is a live repartition (no session restart).
    report.rebuilds = session.partition_epoch() as usize;
    drop(session);
    drop(fleet);
    Ok(report)
}

/// Replay the scenario catalog over a **real loopback TCP fleet** — CDC
/// arm, wall clock (`scenarios --transport tcp`). Process chaos is real:
/// crashes/kills SIGKILL workers, joins are live `Register` handshakes
/// against the coordinator's membership listener, each joiner announces
/// a graceful `Leave` before the horizon, and `Leave` events SIGKILL
/// original workers. With `expect_no_loss`, any failed or balked request
/// fails the run — the zero-loss churn acceptance gate (DESIGN.md §13).
pub fn run_tcp(ctx: &ExpCtx, expect_no_loss: bool) -> Result<()> {
    let arts = synth::build(ctx.seed)?;
    let scale = if ctx.quick { 0.5 } else { 1.0 };
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut lost = 0u64;

    println!("\n=== Scenario suite over a live TCP fleet (cdc arm, wall clock) ===");
    for sc in catalog(ctx.seed) {
        let sc = sc.scaled(scale);
        let report = run_tcp_scenario(&arts.root, &sc)?;
        let s = report.latency.summary();
        lost += report.failed + report.dropped;
        println!("  {}", report.line());
        rows.push(vec![
            sc.name.clone(),
            format!("{}", report.completed),
            format!("{}", report.failed),
            format!("{}", report.recovered),
            format!("{:.1}", report.rps()),
            format!("{:.1}", s.p50),
            format!("{:.1}", s.p99),
            format!("{}", report.rebuilds),
        ]);
        json_rows.push(obj(vec![
            ("scenario", Value::Str(sc.name.clone())),
            ("arm", Value::Str("cdc".into())),
            ("completed", Value::Num(report.completed as f64)),
            ("failed", Value::Num(report.failed as f64)),
            ("recovered", Value::Num(report.recovered as f64)),
            ("dropped", Value::Num(report.dropped as f64)),
            ("rps", Value::Num(report.rps())),
            ("p50_ms", Value::Num(s.p50)),
            ("p99_ms", Value::Num(s.p99)),
            ("makespan_ms", Value::Num(report.makespan_ms)),
            ("repartitions", Value::Num(report.rebuilds as f64)),
        ]));
    }

    print_table(
        &["scenario", "served", "lost", "recovered", "rps", "p50 ms", "p99 ms", "repartitions"],
        &rows,
    );
    ctx.write_result(
        "scenarios_tcp",
        &obj(vec![
            ("experiment", Value::Str("scenario_suite_tcp".into())),
            ("backend", Value::Str(crate::runtime::backend_label().into())),
            ("scale", Value::Num(scale)),
            ("points", Value::Arr(json_rows)),
        ]),
    )?;
    if expect_no_loss && lost > 0 {
        return Err(Error::Fleet(format!(
            "--expect-no-loss: {lost} request(s) lost/balked across the TCP scenario suite"
        )));
    }
    Ok(())
}
