//! Interpreter compute backend: executes shard artifacts directly from
//! their manifest metadata with the in-tree [`Tensor`] ops.
//!
//! The AOT artifacts implement exactly two program shapes (see
//! `python/compile/model.py`):
//!
//! * `fc_shard`:  `(w (m,k), b (m,1), x (k,n)) → w@x + b [relu]`
//! * `conv_shard`: `(w (k_s, f²c), b (k_s,1), x (h,w,c)) →
//!   gemm(w, im2col(x)) + b [relu]` reshaped to `(oh, ow, k_s)`
//!
//! so a faithful CPU interpreter needs only a GEMM and an `im2col` that
//! mirror `python/compile/kernels/ref.py` (same padding arithmetic, same
//! patch unroll order). This backend keeps every test, example, and
//! experiment runnable on a machine with no XLA/PJRT installation; the
//! `pjrt` feature swaps in the compiled path with identical semantics.

use std::cell::Cell;

use crate::error::{Error, Result};
use crate::runtime::manifest::{ArtifactKind, ArtifactMeta};
use crate::runtime::GemmExec;
use crate::tensor::Tensor;

/// Stateless-ish interpreter (only an exec counter).
pub struct InterpRuntime {
    execs: Cell<u64>,
}

impl Default for InterpRuntime {
    fn default() -> Self {
        InterpRuntime::new()
    }
}

impl InterpRuntime {
    /// Create an interpreter backend.
    pub fn new() -> InterpRuntime {
        InterpRuntime { execs: Cell::new(0) }
    }

    /// Total execute() calls served.
    pub fn exec_count(&self) -> u64 {
        self.execs.get()
    }

    /// Execute an artifact by metadata. Inputs are pre-validated against
    /// `meta.params` by the facade.
    pub fn execute(&self, meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<Tensor> {
        self.execs.set(self.execs.get() + 1);
        match meta.kind {
            ArtifactKind::Fc => fc_shard(inputs[0], inputs[1], inputs[2], meta.relu),
            ArtifactKind::Conv => {
                let geom = meta.geom.as_ref().ok_or_else(|| {
                    Error::Artifact(format!(
                        "conv artifact {} carries no geometry (f/s/padding); \
                         rebuild artifacts with compile/aot.py or use the \
                         pjrt backend",
                        meta.name
                    ))
                })?;
                conv_shard(
                    inputs[0],
                    inputs[1],
                    inputs[2],
                    geom.f,
                    geom.s,
                    &geom.padding,
                    meta.relu,
                )
            }
        }
    }

    /// Execute a built GEMM spec `(w, x[, b])`, counting the execution.
    pub fn run_gemm(&self, spec: &GemmExec, inputs: &[&Tensor]) -> Result<Tensor> {
        self.execs.set(self.execs.get() + 1);
        InterpRuntime::run_gemm_spec(spec, inputs)
    }

    /// Execute a built GEMM spec without touching any backend state.
    pub fn run_gemm_spec(spec: &GemmExec, inputs: &[&Tensor]) -> Result<Tensor> {
        let want = if spec.bias { 3 } else { 2 };
        if inputs.len() != want {
            return Err(Error::Shape(format!(
                "gemm fallback: expected {want} inputs, got {}",
                inputs.len()
            )));
        }
        let (w, x) = (inputs[0], inputs[1]);
        if w.shape() != [spec.m, spec.k] || x.shape() != [spec.k, spec.n] {
            return Err(Error::Shape(format!(
                "gemm fallback: w {:?} x {:?} vs spec ({},{})x({},{})",
                w.shape(),
                x.shape(),
                spec.m,
                spec.k,
                spec.k,
                spec.n
            )));
        }
        let mut out = w.matmul(x)?;
        if spec.bias {
            add_bias_rows(&mut out, inputs[2])?;
        }
        if spec.relu {
            out.relu();
        }
        Ok(out)
    }
}

/// fc shard: `w@x + b [relu]` with the bias column broadcast over n.
fn fc_shard(w: &Tensor, b: &Tensor, x: &Tensor, relu: bool) -> Result<Tensor> {
    let mut out = w.matmul(x)?;
    add_bias_rows(&mut out, b)?;
    if relu {
        out.relu();
    }
    Ok(out)
}

/// Add a (m,1) bias column to every column of a (m,n) matrix in place.
fn add_bias_rows(out: &mut Tensor, b: &Tensor) -> Result<()> {
    let (m, n) = match out.shape()[..] {
        [m, n] => (m, n),
        _ => return Err(Error::Shape(format!("bias add on {:?}", out.shape()))),
    };
    if b.shape() != [m, 1] {
        return Err(Error::Shape(format!(
            "bias shape {:?} vs output rows {m}",
            b.shape()
        )));
    }
    let bd = b.data().to_vec();
    for (i, row) in out.data_mut().chunks_mut(n).enumerate() {
        let bv = bd[i];
        for v in row {
            *v += bv;
        }
    }
    Ok(())
}

/// conv shard: im2col + GEMM + reshape/transpose to `(oh, ow, k_s)`,
/// mirroring `conv_shard_fn` in `python/compile/model.py`.
fn conv_shard(
    w: &Tensor,
    b: &Tensor,
    x: &Tensor,
    f: usize,
    stride: usize,
    padding: &str,
    relu: bool,
) -> Result<Tensor> {
    let (cols, oh, ow) = im2col(x, f, stride, padding)?;
    let mut out = w.matmul(&cols)?; // (k_s, oh*ow)
    add_bias_rows(&mut out, b)?;
    if relu {
        out.relu();
    }
    // (k_s, oh*ow) row-major → (oh, ow, k_s) row-major.
    let ks = out.shape()[0];
    let od = out.data();
    let mut data = vec![0.0f32; oh * ow * ks];
    for c in 0..ks {
        let src = &od[c * (oh * ow)..(c + 1) * (oh * ow)];
        for (p, &v) in src.iter().enumerate() {
            data[p * ks + c] = v;
        }
    }
    Tensor::new(vec![oh, ow, ks], data)
}

/// Patch unroll (paper Fig. 4): `(H, W, C) → (F²C, OH·OW)`. Column `j`
/// holds the receptive field of output pixel `j`, flattened in
/// `(di, dj, channel)` order; SAME padding splits `floor/ceil` like
/// `jnp.pad` in the reference (`ph/2` on top, the remainder below).
pub fn im2col(x: &Tensor, f: usize, stride: usize, padding: &str) -> Result<(Tensor, usize, usize)> {
    if stride == 0 || f == 0 {
        return Err(Error::Shape("im2col: zero filter/stride".into()));
    }
    let (h, w, c) = match x.shape()[..] {
        [h, w, c] => (h, w, c),
        _ => return Err(Error::Shape(format!("im2col of {:?}", x.shape()))),
    };
    let (oh, ow, pad_top, pad_left) = match padding {
        "SAME" => {
            let oh = h.div_ceil(stride);
            let ow = w.div_ceil(stride);
            let ph = ((oh - 1) * stride + f).saturating_sub(h);
            let pw = ((ow - 1) * stride + f).saturating_sub(w);
            (oh, ow, ph / 2, pw / 2)
        }
        "VALID" => {
            if h < f || w < f {
                return Err(Error::Shape(format!(
                    "im2col VALID: input {h}x{w} smaller than filter {f}"
                )));
            }
            ((h - f) / stride + 1, (w - f) / stride + 1, 0, 0)
        }
        other => return Err(Error::Config(format!("unknown padding {other:?}"))),
    };
    let rows = f * f * c;
    let n_cols = oh * ow;
    let mut data = vec![0.0f32; rows * n_cols];
    let xd = x.data();
    for oy in 0..oh {
        for ox in 0..ow {
            let p = oy * ow + ox;
            for di in 0..f {
                let iy = (oy * stride + di) as isize - pad_top as isize;
                if iy < 0 || iy >= h as isize {
                    continue; // zero padding
                }
                for dj in 0..f {
                    let ix = (ox * stride + dj) as isize - pad_left as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = (iy as usize * w + ix as usize) * c;
                    let rbase = (di * f + dj) * c;
                    for ch in 0..c {
                        data[(rbase + ch) * n_cols + p] = xd[src + ch];
                    }
                }
            }
        }
    }
    Ok((Tensor::new(vec![rows, n_cols], data)?, oh, ow))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    /// Direct (naive) convolution oracle for the im2col+GEMM path.
    fn conv_naive(
        x: &Tensor,
        wmat: &Tensor, // (k, f*f*c)
        b: &Tensor,
        f: usize,
        stride: usize,
        same: bool,
    ) -> Tensor {
        let (h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let k = wmat.shape()[0];
        let (oh, ow, pt, pl) = if same {
            let oh = h.div_ceil(stride);
            let ow = w.div_ceil(stride);
            let ph = ((oh - 1) * stride + f).saturating_sub(h);
            let pw = ((ow - 1) * stride + f).saturating_sub(w);
            (oh, ow, ph / 2, pw / 2)
        } else {
            ((h - f) / stride + 1, (w - f) / stride + 1, 0, 0)
        };
        let mut out = vec![0.0f32; oh * ow * k];
        for oy in 0..oh {
            for ox in 0..ow {
                for kk in 0..k {
                    let mut acc = b.data()[kk];
                    for di in 0..f {
                        for dj in 0..f {
                            let iy = (oy * stride + di) as isize - pt as isize;
                            let ix = (ox * stride + dj) as isize - pl as isize;
                            if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                continue;
                            }
                            for ch in 0..c {
                                let xv = x.data()[(iy as usize * w + ix as usize) * c + ch];
                                let wv = wmat.data()[kk * (f * f * c) + (di * f + dj) * c + ch];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[(oy * ow + ox) * k + kk] = acc;
                }
            }
        }
        Tensor::new(vec![oh, ow, k], out).unwrap()
    }

    #[test]
    fn im2col_identity_filter() {
        // f=1, stride=1: columns are just the pixels.
        let x = Tensor::new(vec![2, 2, 1], vec![1., 2., 3., 4.]).unwrap();
        let (cols, oh, ow) = im2col(&x, 1, 1, "SAME").unwrap();
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(cols.shape(), &[1, 4]);
        assert_eq!(cols.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn conv_matches_naive_same_and_valid() {
        let mut rng = Pcg32::seeded(21);
        for (h, w, c, k, f, s, same) in [
            (5usize, 5usize, 2usize, 3usize, 3usize, 1usize, true),
            (6, 6, 1, 2, 3, 2, true),
            (6, 5, 2, 2, 2, 1, false),
            (7, 7, 3, 4, 5, 2, true),
        ] {
            let x = Tensor::randn(vec![h, w, c], &mut rng);
            let wm = Tensor::randn(vec![k, f * f * c], &mut rng);
            let b = Tensor::randn(vec![k, 1], &mut rng);
            let got =
                conv_shard(&wm, &b, &x, f, s, if same { "SAME" } else { "VALID" }, false)
                    .unwrap();
            let want = conv_naive(&x, &wm, &b, f, s, same);
            assert_eq!(got.shape(), want.shape(), "h{h}w{w}c{c}k{k}f{f}s{s}");
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "h{h}w{w}c{c}k{k}f{f}s{s}: diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn fc_shard_bias_and_relu() {
        let w = Tensor::new(vec![2, 2], vec![1., 0., 0., -1.]).unwrap();
        let b = Tensor::new(vec![2, 1], vec![0.5, 0.5]).unwrap();
        let x = Tensor::new(vec![2, 1], vec![1., 2.]).unwrap();
        let lin = fc_shard(&w, &b, &x, false).unwrap();
        assert_eq!(lin.data(), &[1.5, -1.5]);
        let act = fc_shard(&w, &b, &x, true).unwrap();
        assert_eq!(act.data(), &[1.5, 0.0]);
    }

    #[test]
    fn gemm_spec_validates_shapes() {
        let spec = GemmExec {
            m: 2,
            k: 3,
            n: 1,
            bias: false,
            relu: false,
            #[cfg(feature = "pjrt")]
            exe: None,
        };
        let w = Tensor::zeros(vec![2, 3]);
        let x = Tensor::zeros(vec![3, 1]);
        assert!(InterpRuntime::run_gemm_spec(&spec, &[&w, &x]).is_ok());
        let bad = Tensor::zeros(vec![4, 1]);
        assert!(InterpRuntime::run_gemm_spec(&spec, &[&w, &bad]).is_err());
    }
}
