//! Deploy-time packed-weight caching (DESIGN.md §15).
//!
//! The blocked GEMM spends a meaningful slice of every call re-packing
//! the weight operand into MR-row micro-panel strips — work that is
//! identical on every inference because a deployment's weights are
//! immutable between lifecycle verbs. [`PackedWeights`] hoists that
//! packing to deploy time: all `(kc, mc)` panels of the weight matrix
//! are packed once into a single arena-backed allocation, and
//! [`gemm_prepacked`] runs the same macro loop as `gemm_tiled` with the
//! A-packing stage deleted. Because the panels are byte-identical to
//! what `pack_a` would produce in the loop, the prepacked result is
//! bit-for-bit equal to the on-line kernel at every tier.
//!
//! Lifetime rules: a `PackedWeights` is built from (and keyed by) one
//! weight tensor at deploy/redeploy time, shared via `Arc` by the sim
//! device channel, and rebuilt locally by TCP workers when a Deploy
//! frame lands — packed panels never travel on the wire (they are an
//! arch-local layout, and 2× the weight bytes for free at deploy beats
//! shipping them). The original `w` tensor stays in the task inputs, so
//! tiny shapes still take the naive path with zero copies.

use super::gemm::{
    gemm_naive, macro_kernel, pack_a, pack_b, auto_threads, KC, MC, MR, NC, NR,
    THREADED_MIN_FLOPS, TILED_MIN_FLOPS,
};
use super::scratch::{with_scratch, Scratch};
use super::simd::{self, Tier};

/// A weight matrix pre-packed into the blocked GEMM's A-panel layout:
/// every `(k-panel, row-panel)` pair packed by [`pack_a`] into one
/// contiguous arena, plus an offset table indexed
/// `k_panel_index * n_row_panels + row_panel_index`.
#[derive(Clone, PartialEq)]
pub struct PackedWeights {
    m: usize,
    k: usize,
    data: Vec<f32>,
    offsets: Vec<usize>,
}

impl std::fmt::Debug for PackedWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PackedWeights")
            .field("m", &self.m)
            .field("k", &self.k)
            .field("panels", &self.offsets.len())
            .field("bytes", &self.bytes())
            .finish()
    }
}

impl PackedWeights {
    /// Pack a row-major `m × k` weight matrix. Deploy-time cost: one
    /// pass over the weights; the arena holds every panel zero-padded
    /// to full MR strips, exactly as the in-loop `pack_a` would.
    pub fn pack(w: &[f32], m: usize, k: usize) -> PackedWeights {
        assert_eq!(w.len(), m * k, "PackedWeights: weight length vs ({m},{k})");
        let n_ip = m.div_ceil(MC);
        let n_pc = k.div_ceil(KC);
        let mut data = Vec::new();
        let mut offsets = Vec::with_capacity(n_ip * n_pc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                let off = data.len();
                offsets.push(off);
                data.resize(off + mc.div_ceil(MR) * MR * kc, 0.0);
                pack_a(w, &mut data[off..], ic, pc, mc, kc, k);
                ic += MC;
            }
            pc += KC;
        }
        PackedWeights { m, k, data, offsets }
    }

    /// (rows, depth) of the packed matrix.
    pub fn dims(&self) -> (usize, usize) {
        (self.m, self.k)
    }

    /// Arena size in bytes (offset table excluded).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Whether packing `m × k` weights at deploy time can ever pay off:
    /// true when the smallest blocked-path multiply (`n = NR`) clears
    /// the tiled FLOP floor. Below that every call takes the naive
    /// GEMV path and the packed arena would be dead weight.
    pub fn pays_off(m: usize, k: usize) -> bool {
        2.0 * m as f64 * k as f64 * NR as f64 >= TILED_MIN_FLOPS
    }

    /// The packed panel for k-panel `pc_i` and row-panel `ic_i`.
    fn panel(&self, pc_i: usize, ic_i: usize) -> &[f32] {
        let n_ip = self.m.div_ceil(MC);
        let idx = pc_i * n_ip + ic_i;
        let end = self.offsets.get(idx + 1).copied().unwrap_or(self.data.len());
        &self.data[self.offsets[idx]..end]
    }
}

/// The `gemm_tiled` macro loop restricted to row panels
/// `[ip_start, ip_end)`, reading A panels from the arena instead of
/// packing them. `c_band` starts at row `ip_start * MC`.
#[allow(clippy::too_many_arguments)]
fn prepacked_band(
    pw: &PackedWeights,
    b: &[f32],
    c_band: &mut [f32],
    ip_start: usize,
    ip_end: usize,
    n: usize,
    scratch: &mut Scratch,
    tier: Tier,
) {
    let k = pw.k;
    let band_row0 = ip_start * MC;
    let mut bpack = scratch.take(KC * NC);
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        let mut pc_i = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, &mut bpack, pc, jc, kc, nc, n);
            for ip in ip_start..ip_end {
                let ic = ip * MC;
                let mc = MC.min(pw.m - ic);
                macro_kernel(
                    pw.panel(pc_i, ip),
                    &bpack,
                    c_band,
                    ic - band_row0,
                    jc,
                    mc,
                    nc,
                    kc,
                    n,
                    tier,
                );
            }
            pc += KC;
            pc_i += 1;
        }
        jc += NC;
    }
    scratch.put(bpack);
}

/// Single-threaded blocked GEMM over pre-packed weights:
/// `c = pw @ b`, bit-identical to `gemm_tiled_with` on the unpacked
/// weights at the same tier, minus the per-call A packing.
pub fn gemm_prepacked(
    pw: &PackedWeights,
    b: &[f32],
    c: &mut [f32],
    n: usize,
    scratch: &mut Scratch,
    tier: Tier,
) {
    let (m, k) = pw.dims();
    assert_eq!(b.len(), k * n, "gemm_prepacked: rhs length vs ({k},{n})");
    assert_eq!(c.len(), m * n, "gemm_prepacked: out length vs ({m},{n})");
    assert!(simd::tier_supported(tier), "micro-kernel tier {tier:?} unsupported here");
    c.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    prepacked_band(pw, b, c, 0, m.div_ceil(MC), n, scratch, tier);
}

/// Multi-threaded prepacked GEMM: row panels are partitioned into up to
/// `threads` contiguous MC-aligned bands (each worker reads its panels
/// straight from the shared arena, packs only its B panels).
pub fn gemm_prepacked_threaded(
    pw: &PackedWeights,
    b: &[f32],
    c: &mut [f32],
    n: usize,
    threads: usize,
    tier: Tier,
) {
    let (m, k) = pw.dims();
    assert_eq!(b.len(), k * n, "gemm_prepacked: rhs length vs ({k},{n})");
    assert_eq!(c.len(), m * n, "gemm_prepacked: out length vs ({m},{n})");
    assert!(simd::tier_supported(tier), "micro-kernel tier {tier:?} unsupported here");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let n_ip = m.div_ceil(MC);
    let t = threads.max(1).min(n_ip);
    if t <= 1 {
        c.fill(0.0);
        with_scratch(|sc| prepacked_band(pw, b, c, 0, n_ip, n, sc, tier));
        return;
    }
    let per = n_ip.div_ceil(t);
    c.fill(0.0);
    std::thread::scope(|s| {
        for (bi, c_band) in c.chunks_mut(per * MC * n).enumerate() {
            let ip0 = bi * per;
            let ip1 = (ip0 + per).min(n_ip);
            s.spawn(move || {
                let mut sc = Scratch::new();
                prepacked_band(pw, b, c_band, ip0, ip1, n, &mut sc, tier);
            });
        }
    });
}

/// Prepacked twin of `gemm_auto`: the same dispatch ladder (naive for
/// tiny shapes / GEMV, threaded above the FLOP floor, tiled otherwise)
/// with the blocked paths reading from the arena. `w` is the original
/// unpacked weight matrix, used only by the naive fallback — the serve
/// hot path keeps both views alive, so no shape ever repacks or copies.
pub fn gemm_prepacked_auto(
    pw: &PackedWeights,
    w: &[f32],
    b: &[f32],
    c: &mut [f32],
    n: usize,
    scratch: &mut Scratch,
) {
    let (m, k) = pw.dims();
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let tier = simd::select();
    if n < NR || flops < TILED_MIN_FLOPS {
        gemm_naive(w, b, c, m, k, n);
    } else if flops >= THREADED_MIN_FLOPS && auto_threads() > 1 {
        gemm_prepacked_threaded(pw, b, c, n, auto_threads(), tier);
    } else {
        gemm_prepacked(pw, b, c, n, scratch, tier);
    }
}

#[cfg(test)]
mod tests {
    use super::super::gemm::{gemm_tiled, gemm_tiled_with};
    use super::*;
    use crate::rng::Pcg32;

    fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn prepacked_bitwise_matches_tiled() {
        // Multi-panel shapes in every dimension: m > MC, k > KC, n > NC.
        let mut rng = Pcg32::seeded(21);
        let mut sc = Scratch::new();
        for &(m, k, n) in &[
            (1, 1, 8),
            (4, 8, 8),
            (65, 67, 63),
            (130, 300, 520),
            (64, 512, 16),
            (200, 40, 9),
        ] {
            let w = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let pw = PackedWeights::pack(&w, m, k);
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![1.0; m * n];
            gemm_tiled(&w, &b, &mut c0, m, k, n, &mut sc);
            gemm_prepacked(&pw, &b, &mut c1, n, &mut sc, Tier::Scalar);
            assert_eq!(c0, c1, "({m},{k},{n})");
            // Active tier (may be SIMD): still bitwise-equal to the
            // tiled kernel at that same tier.
            let tier = simd::select();
            gemm_tiled_with(&w, &b, &mut c0, m, k, n, &mut sc, tier);
            gemm_prepacked(&pw, &b, &mut c1, n, &mut sc, tier);
            assert_eq!(c0, c1, "({m},{k},{n}) tier {tier:?}");
        }
    }

    #[test]
    fn prepacked_threaded_bitwise_matches_single() {
        let mut rng = Pcg32::seeded(22);
        let mut sc = Scratch::new();
        let (m, k, n) = (300, 200, 96);
        let w = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let pw = PackedWeights::pack(&w, m, k);
        let mut c0 = vec![0.0; m * n];
        gemm_prepacked(&pw, &b, &mut c0, n, &mut sc, Tier::Scalar);
        for threads in [1, 2, 3, 8] {
            let mut c1 = vec![1.0; m * n];
            gemm_prepacked_threaded(&pw, &b, &mut c1, n, threads, Tier::Scalar);
            assert_eq!(c0, c1, "threads={threads}");
        }
    }

    #[test]
    fn prepacked_auto_matches_auto_everywhere() {
        let mut rng = Pcg32::seeded(23);
        let mut sc = Scratch::new();
        // Spans the naive (GEMV), tiled and threaded rungs.
        for &(m, k, n) in &[(8, 16, 1), (120, 400, 1), (64, 512, 16), (256, 256, 256)] {
            let w = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            let pw = PackedWeights::pack(&w, m, k);
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![1.0; m * n];
            super::super::gemm_auto(&w, &b, &mut c0, m, k, n, &mut sc);
            gemm_prepacked_auto(&pw, &w, &b, &mut c1, n, &mut sc);
            assert_eq!(c0, c1, "({m},{k},{n})");
        }
    }

    #[test]
    fn pays_off_thresholds() {
        assert!(PackedWeights::pays_off(512, 2048));
        assert!(PackedWeights::pays_off(120, 400));
        assert!(!PackedWeights::pays_off(6, 25));
        assert_eq!(PackedWeights::pack(&[], 0, 0).bytes(), 0);
    }
}
