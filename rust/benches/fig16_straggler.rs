//! Bench target for Fig. 16 (straggler mitigation sweep) plus the
//! ablations DESIGN.md §6 calls out: the substitution-threshold sweep and
//! the policy-resolution cost (the coordinator's per-layer decision must
//! be negligible next to shard service times).
//!
//! Run with `cargo bench --bench fig16_straggler` after `make artifacts`.

use cdc_dnn::bench::Bench;
use cdc_dnn::coordinator::policy;
use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec};
use cdc_dnn::metrics::Series;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::tensor::Tensor;

fn fc_cfg(d: usize, threshold: f64, seed: u64) -> SessionConfig {
    let mut cfg = SessionConfig::new("fc2048");
    cfg.n_devices = d;
    cfg.seed = seed;
    cfg.threshold_factor = threshold;
    cfg.splits.insert("fc".into(), SplitSpec::cdc(d));
    cfg
}

fn mean_latency(d: usize, threshold: f64, reqs: usize) -> f64 {
    let mut s = Session::start("artifacts", fc_cfg(d, threshold, 7)).unwrap();
    let mut rng = Pcg32::seeded(11);
    let mut lat = Series::new();
    for _ in 0..reqs {
        let x = Tensor::randn(vec![2048], &mut rng);
        lat.record(s.infer(&x).unwrap().total_ms);
    }
    lat.summary().mean
}

fn main() {
    let backend = cdc_dnn::runtime::backend_label();
    if !cdc_dnn::testkit::artifacts_available(std::path::Path::new("artifacts")) {
        println!(
            "[skip] fig16_straggler: AOT artifacts absent (would run on \
             backend: {backend})"
        );
        return;
    }
    println!("fig16_straggler: compute backend = {backend}");
    let reqs = 150;

    // Fig. 16 series: improvement vs device count.
    println!("fig16: mitigation improvement vs devices (n={reqs} requests)");
    for d in [2usize, 4, 8] {
        let off = mean_latency(d, f64::INFINITY, reqs);
        let on = mean_latency(d, 0.0, reqs);
        println!(
            "  d={d}: no-mit {off:.1} ms, mit {on:.1} ms, improvement {:.1}%",
            100.0 * (1.0 - on / off)
        );
    }

    // Ablation: threshold-factor sweep at d=4 (paper §6.2: "a lower
    // threshold reduces latency").
    println!("\nablation: threshold sweep at d=4");
    for t in [0.0, 2.0, 8.0, 24.0, f64::INFINITY] {
        let m = mean_latency(4, t, reqs);
        println!("  threshold_factor={t}: mean {m:.1} ms");
    }

    // Wall-clock of one mitigated request (coordination overhead incl.).
    let mut s = Session::start("artifacts", fc_cfg(4, 0.0, 3)).unwrap();
    let mut rng = Pcg32::seeded(13);
    let x = Tensor::randn(vec![2048], &mut rng);
    s.infer(&x).unwrap();
    Bench::new("fig16/request_wallclock_d4_mitigated").iters(5, 50).run(|| {
        s.infer(&x).unwrap();
    });

    // Pure policy resolution cost.
    let data: Vec<f64> = (0..8).map(|i| 50.0 + i as f64).collect();
    Bench::new("policy/resolve_grouped_8shards")
        .iters(1000, 10_000)
        .run(|| {
            std::hint::black_box(policy::resolve_grouped(
                std::hint::black_box(&data),
                &[60.0],
                &[vec![0, 1, 2, 3, 4, 5, 6, 7]],
                75.0,
            ));
        });
}
