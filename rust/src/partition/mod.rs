//! Model-parallel splitting methods (paper §4) and their matrix-level
//! properties (§5.1, Table 1), plus shard-plan construction.
//!
//! Mirrors `python/compile/splits.py`; the two are kept in sync by the
//! golden-manifest tests.

use crate::error::{Error, Result};
use crate::model::Weights;
use crate::runtime::manifest::LayerManifest;
use crate::tensor::Tensor;

/// The five distribution methods of Section 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SplitMethod {
    /// fc: each device computes a row-slice of the output (Fig. 5a/6).
    OutputSplit,
    /// fc: each device holds a column-slice of W and an input slice (Fig. 5b/7).
    InputSplit,
    /// conv: each device holds a subset of filters (Fig. 8).
    ChannelSplit,
    /// conv: each device processes a spatial slice of the input (Fig. 9).
    SpatialSplit,
    /// conv: depth-wise split of both filters and input (Fig. 10).
    FilterSplit,
}

/// Matrix-level properties of a split method (one row of Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitProps {
    pub layer: &'static str,
    pub divides_input: bool,
    pub divides_weight: bool,
    pub divides_output: bool,
}

impl SplitMethod {
    /// All methods in Table 1 order.
    pub const ALL: [SplitMethod; 5] = [
        SplitMethod::OutputSplit,
        SplitMethod::InputSplit,
        SplitMethod::ChannelSplit,
        SplitMethod::SpatialSplit,
        SplitMethod::FilterSplit,
    ];

    /// Table-1 row for this method.
    pub fn props(self) -> SplitProps {
        match self {
            SplitMethod::OutputSplit => SplitProps {
                layer: "fc",
                divides_input: false,
                divides_weight: true,
                divides_output: true,
            },
            SplitMethod::InputSplit => SplitProps {
                layer: "fc",
                divides_input: true,
                divides_weight: true,
                divides_output: false,
            },
            SplitMethod::ChannelSplit => SplitProps {
                layer: "conv",
                divides_input: false,
                divides_weight: true,
                divides_output: true,
            },
            SplitMethod::SpatialSplit => SplitProps {
                layer: "conv",
                divides_input: true,
                divides_weight: false,
                divides_output: true,
            },
            SplitMethod::FilterSplit => SplitProps {
                layer: "conv",
                divides_input: true,
                divides_weight: true,
                divides_output: true,
            },
        }
    }

    /// The paper's §5.3 criterion: a method admits library-level CDC iff
    /// it divides the weights *without* dividing the input — only then can
    /// the parity weights be summed offline, input-independently.
    pub fn cdc_suitable(self) -> bool {
        let p = self.props();
        p.divides_weight && !p.divides_input
    }

    /// Method name as printed in Table 1.
    pub fn name(self) -> &'static str {
        match self {
            SplitMethod::OutputSplit => "Output",
            SplitMethod::InputSplit => "Input",
            SplitMethod::ChannelSplit => "Channel",
            SplitMethod::SpatialSplit => "Spatial",
            SplitMethod::FilterSplit => "Filter",
        }
    }

    /// The CDC-suitable method for a layer kind.
    pub fn suitable_for(kind: &str) -> Option<SplitMethod> {
        match kind {
            "fc" => Some(SplitMethod::OutputSplit),
            "conv" => Some(SplitMethod::ChannelSplit),
            _ => None,
        }
    }
}

/// Split `total` into `parts` contiguous ranges differing by ≤ 1 in size.
pub fn balanced_ranges(total: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0, "parts must be positive");
    let base = total / parts;
    let extra = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let size = base + usize::from(i < extra);
        out.push((start, start + size));
        start += size;
    }
    out
}

/// One device's slice of a layer under output/channel splitting.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Shard index within the layer (0..d).
    pub index: usize,
    /// Row range [lo, hi) of the full weight matrix this shard owns.
    pub rows: (usize, usize),
    /// Uniform shard height (ceil(m/d)); rows beyond `hi-lo` are zero pad.
    pub height: usize,
}

/// The split plan of one layer: `d` uniform shards (+ optional parity).
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub layer: String,
    pub method: SplitMethod,
    pub d: usize,
    pub shards: Vec<ShardSpec>,
    /// Artifact names for the two epilogue flavors.
    pub artifact_lin: String,
    pub artifact_relu: Option<String>,
}

impl LayerPlan {
    /// Build the plan for a weighted layer split `d` ways with its
    /// CDC-suitable method. Errors if the manifest carries no artifacts
    /// for this degree.
    pub fn build(layer: &LayerManifest, d: usize) -> Result<LayerPlan> {
        let method = SplitMethod::suitable_for(&layer.kind).ok_or_else(|| {
            Error::Config(format!("layer kind {} is not distributable", layer.kind))
        })?;
        let arts = layer.splits.get(&d).ok_or_else(|| {
            Error::Config(format!(
                "layer {} has no artifacts for split degree {d} (available: {:?})",
                layer.name,
                layer.splits.keys().collect::<Vec<_>>()
            ))
        })?;
        let total = if layer.kind == "fc" { layer.m } else { layer.k };
        let height = total.div_ceil(d);
        let shards = (0..d)
            .map(|i| ShardSpec {
                index: i,
                rows: (i * height, ((i + 1) * height).min(total)),
                height,
            })
            .collect();
        Ok(LayerPlan {
            layer: layer.name.clone(),
            method,
            d,
            shards,
            artifact_lin: arts.lin.clone(),
            artifact_relu: arts.relu.clone(),
        })
    }

    /// Total real (unpadded) rows across shards — must equal the layer
    /// height (balanced-assignment invariant).
    pub fn covered_rows(&self) -> usize {
        self.shards.iter().map(|s| s.rows.1 - s.rows.0).sum()
    }

    /// Slice one shard's (zero-padded) weights out of the full matrices.
    pub fn shard_weights(
        &self,
        weights: &Weights,
        spec: &ShardSpec,
    ) -> Result<(Tensor, Tensor)> {
        let w = weights.w(&self.layer)?;
        let b = weights.b(&self.layer)?;
        let k = w.shape()[1];
        let (lo, hi) = spec.rows;
        let mut wd = vec![0.0f32; spec.height * k];
        wd[..(hi - lo) * k].copy_from_slice(&w.data()[lo * k..hi * k]);
        let mut bd = vec![0.0f32; spec.height];
        bd[..hi - lo].copy_from_slice(&b.data()[lo..hi]);
        Ok((
            Tensor::new(vec![spec.height, k], wd)?,
            Tensor::new(vec![spec.height, 1], bd)?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reproduced() {
        // Exactly the Yes/No column of Table 1.
        use SplitMethod::*;
        assert!(OutputSplit.cdc_suitable());
        assert!(!InputSplit.cdc_suitable());
        assert!(ChannelSplit.cdc_suitable());
        assert!(!SpatialSplit.cdc_suitable());
        assert!(!FilterSplit.cdc_suitable());
    }

    #[test]
    fn suitability_criterion_matches_props() {
        for m in SplitMethod::ALL {
            let p = m.props();
            assert_eq!(m.cdc_suitable(), p.divides_weight && !p.divides_input);
        }
    }

    #[test]
    fn balanced_ranges_cover_and_balance() {
        for total in [1usize, 7, 10, 120, 2048] {
            for parts in [1usize, 2, 3, 4, 7] {
                let r = balanced_ranges(total, parts);
                assert_eq!(r.len(), parts);
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, total);
                let sizes: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
                let max = sizes.iter().max().unwrap();
                let min = sizes.iter().min().unwrap();
                assert!(max - min <= 1, "{total}/{parts}: {sizes:?}");
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous");
                }
            }
        }
    }
}
