//! In-tree micro-benchmark harness (offline environment: no criterion).
//!
//! `cargo bench` targets use [`Bench`] for wall-clock measurements of the
//! hot paths (PJRT dispatch, CDC decode, merge) and the experiment drivers
//! reuse [`Timer`] for coarse phase timing. Reports mean/p50/p95/p99 over
//! a warmed-up sample set, criterion-style.
//!
//! [`guard_baseline`] is the CI perf-trajectory gate: every bench hands
//! it its headline bigger-is-better metrics (rps, GFLOP/s), and it
//! compares them against the committed seed under `rust/baselines/` —
//! failing the run on a > [`BASELINE_TOLERANCE`] regression when
//! `BENCH_BASELINE_ENFORCE` is set.

use std::path::PathBuf;
use std::time::Instant;

use crate::json::{obj, Value};
use crate::metrics::Summary;

/// Allowed fractional regression vs the committed baseline before the
/// guard fails the run (0.15 = a metric may drop to 85% of its seed).
pub const BASELINE_TOLERANCE: f64 = 0.15;

/// Path of the committed baseline seed for bench `name`
/// (`rust/baselines/BENCH_<name>.json`, resolved from the crate root so
/// benches can run from any cwd).
pub fn baseline_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("baselines")
        .join(format!("BENCH_{name}.json"))
}

/// Perf-trajectory guard (CI: the bench matrix runs every bench with
/// `BENCH_BASELINE_ENFORCE=1`). `fresh` are this run's headline metrics,
/// bigger-is-better (rps, GFLOP/s). Each is compared to the same key in
/// the committed seed's `"metrics"` object:
///
/// * metric present in the baseline and fresh < (1 − tolerance) ×
///   baseline → regression; panics when `BENCH_BASELINE_ENFORCE` is set,
///   warns otherwise;
/// * metric absent from the baseline → bootstrap mode: the value is
///   printed in promotable JSON form and skipped (seeds are committed
///   empty and promoted from CI artifact uploads, so the guard never
///   fails on numbers nobody measured).
///
/// Every call also writes `BENCH_<name>.metrics.json` (seed-shaped,
/// into `$BENCH_METRICS_DIR` or the cwd) — the artifact
/// `scripts/promote_baselines.sh` merges into `rust/baselines/`.
pub fn guard_baseline(name: &str, fresh: &[(String, f64)]) {
    let enforce = std::env::var("BENCH_BASELINE_ENFORCE").is_ok();
    let path = baseline_path(name);
    let text = std::fs::read_to_string(&path).ok();
    let baseline = text.and_then(|s| Value::parse(&s).ok());
    let mut fresh_map = std::collections::BTreeMap::new();
    for (k, v) in fresh {
        fresh_map.insert(k.clone(), Value::Num(*v));
    }
    let metrics_json = obj(vec![("metrics", Value::Obj(fresh_map))]);
    println!(
        "[baseline] {name}: fresh headline metrics (promote into {}):\n{}",
        path.display(),
        metrics_json.to_string_pretty()
    );
    // Also drop the promotable form on disk: CI uploads `BENCH_*.json`
    // artifacts and `scripts/promote_baselines.sh` folds these into the
    // committed seeds under `rust/baselines/`. The file is exactly the
    // seed shape (`{"metrics": {...}}`), so promotion is a merge, not a
    // transformation. Best-effort: an unwritable cwd must not fail a
    // bench run.
    let out_dir = std::env::var("BENCH_METRICS_DIR").unwrap_or_else(|_| ".".into());
    let out = PathBuf::from(out_dir).join(format!("BENCH_{name}.metrics.json"));
    match std::fs::write(&out, metrics_json.to_string_pretty()) {
        Ok(()) => println!("[baseline] {name}: wrote promotable {}", out.display()),
        Err(e) => println!("[baseline] {name}: could not write {}: {e}", out.display()),
    }
    let Some(baseline) = baseline else {
        println!("[baseline] {name}: no committed seed — bootstrap, nothing enforced");
        return;
    };
    let mut regressions = Vec::new();
    for (key, value) in fresh {
        let Some(seed) = baseline.opt("metrics").and_then(|m| m.opt(key)) else {
            println!("[baseline] {name}/{key}: not in seed — bootstrap, skipped");
            continue;
        };
        let seed = seed.as_f64().unwrap_or(f64::NAN);
        if !seed.is_finite() || seed <= 0.0 {
            println!("[baseline] {name}/{key}: unusable seed {seed} — skipped");
        } else if *value < (1.0 - BASELINE_TOLERANCE) * seed {
            regressions.push(format!(
                "{key}: {value:.3} < {:.3} ({}% of seed {seed:.3})",
                (1.0 - BASELINE_TOLERANCE) * seed,
                (100.0 * (1.0 - BASELINE_TOLERANCE)) as u32,
            ));
        } else {
            println!("[baseline] {name}/{key}: {value:.3} vs seed {seed:.3} — ok");
        }
    }
    if regressions.is_empty() {
        return;
    }
    let msg = format!(
        "perf-trajectory regression vs {} (>{:.0}% drop):\n  {}",
        path.display(),
        100.0 * BASELINE_TOLERANCE,
        regressions.join("\n  ")
    );
    if enforce {
        panic!("{msg}");
    }
    println!("[baseline] WARNING (not enforced): {msg}");
}

/// One benchmark's configuration.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    iters: usize,
}

impl Bench {
    /// Default: 10 warm-up + 100 measured iterations.
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup_iters: 10, iters: 100 }
    }

    /// Override iteration counts.
    pub fn iters(mut self, warmup: usize, measured: usize) -> Bench {
        self.warmup_iters = warmup;
        self.iters = measured;
        self
    }

    /// Run the closure repeatedly; returns (and prints) the summary of
    /// per-iteration wall-clock milliseconds.
    pub fn run<F: FnMut()>(self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let s = Summary::of(&samples);
        println!(
            "bench {:<40} mean={:>9.4}ms p50={:>9.4}ms p95={:>9.4}ms p99={:>9.4}ms (n={})",
            self.name, s.mean, s.p50, s.p95, s.p99, s.count
        );
        s
    }
}

/// Coarse phase timer for experiment drivers.
pub struct Timer {
    t0: Instant,
    label: String,
}

impl Timer {
    /// Start a labelled timer.
    pub fn start(label: &str) -> Timer {
        Timer { t0: Instant::now(), label: label.to_string() }
    }

    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    /// Print and return elapsed ms.
    pub fn report(&self) -> f64 {
        let ms = self.ms();
        println!("[time] {}: {:.1} ms", self.label, ms);
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = Bench::new("noop").iters(2, 20).run(|| {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.count, 20);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn baseline_path_is_rooted_in_crate() {
        let p = baseline_path("gemm");
        assert!(p.ends_with("baselines/BENCH_gemm.json"), "{}", p.display());
    }

    #[test]
    fn guard_baseline_bootstraps_without_a_seed() {
        // No committed seed for this name: the guard must report and
        // return, never panic (bootstrap mode).
        guard_baseline(
            "no_such_bench_seed",
            &[("rps".to_string(), 123.0)],
        );
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start("t");
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.ms() >= 2.0);
    }
}
