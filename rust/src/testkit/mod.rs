//! Tiny property-testing substrate (offline environment: no proptest).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it reports the case index, the
//! reproducing seed, and a Debug dump of the failing input. Used by
//! `rust/tests/properties.rs` for the coordinator/CDC invariants.

use crate::rng::Pcg32;

/// Run `prop` over `cases` generated inputs; panics with a reproducible
/// seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        // Each case gets an independent, reconstructible stream.
        let mut rng = Pcg32::new(seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// True when a python-built artifact set exists at `root`; prints the
/// standard skip note otherwise. Artifact-gated tests and benches share
/// this so the skip rule lives in one place.
pub fn artifacts_available(root: &std::path::Path) -> bool {
    let ok = root.join("manifest.json").exists();
    if !ok {
        eprintln!(
            "skipping: no artifacts at {} (build with `make artifacts`)",
            root.display()
        );
    }
    ok
}

/// Synthetic artifact sets: a complete on-disk manifest (model + weights
/// + eval set) built from a seed, with **no** python/AOT build step.
///
/// The manifest describes a small fc-only MLP whose artifacts the
/// interpreter backend executes straight from their metadata, so
/// integration tests, benches, and CI exercise the full coordinator stack
/// (deploy → dispatch → CDC recovery → merge → serve pipeline) offline.
/// The referenced HLO files are not written — running a synthetic set on
/// the `pjrt` backend is not supported.
pub mod synth {
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    use crate::error::{Error, Result};
    use crate::json::{obj, Value};
    use crate::rng::Pcg32;

    /// Name of the synthetic model.
    pub const MODEL: &str = "mlp";
    /// fc1: 18×12 with relu, split degrees {1, 2, 4}.
    pub const FC1_M: usize = 18;
    pub const FC1_K: usize = 12;
    /// fc2: 10×18 logits (no relu), split degrees {1, 2}.
    pub const FC2_M: usize = 10;
    /// Eval-set size.
    pub const EVAL_COUNT: usize = 4;

    /// Name of the wide synthetic model (fleet-width benchmarks).
    pub const WIDE_MODEL: &str = "mlp_wide";
    /// Wide fc1/fc2 height: 434 = lcm(2, 14, 62), so the shard heights
    /// divide evenly at every fleet width the `transport_loopback` bench
    /// sweeps ({4, 16, 64} workers → split degrees {2, 14, 62}; the
    /// partitioner requires `(d-1)·⌈m/d⌉ ≤ m`).
    pub const WIDE_M: usize = 434;
    /// Wide fc1 input width (kept small — the bench is transport-bound,
    /// not GEMM-bound).
    pub const WIDE_K: usize = 8;
    /// Split degrees both wide layers carry artifacts for.
    pub const WIDE_DEGREES: [usize; 4] = [1, 2, 14, 62];

    /// A materialised synthetic artifact directory.
    #[derive(Debug)]
    pub struct SynthArtifacts {
        pub root: PathBuf,
    }

    static COUNTER: AtomicU64 = AtomicU64::new(0);

    fn usize_arr(v: &[usize]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    fn fc_artifact(m_s: usize, k: usize, relu: bool) -> (String, Value) {
        let name = format!("fc_m{m_s}_k{k}_{}", if relu { "relu" } else { "lin" });
        let v = obj(vec![
            ("name", Value::Str(name.clone())),
            ("file", Value::Str(format!("hlo/{name}.hlo.txt"))),
            ("kind", Value::Str("fc".into())),
            ("relu", Value::Bool(relu)),
            (
                "params",
                Value::Arr(vec![
                    usize_arr(&[m_s, k]),
                    usize_arr(&[m_s, 1]),
                    usize_arr(&[k, 1]),
                ]),
            ),
        ]);
        (name, v)
    }

    fn fc_layer(
        name: &str,
        m: usize,
        k: usize,
        relu: bool,
        w_offset: usize,
        b_offset: usize,
        degrees: &[usize],
    ) -> Value {
        let splits: BTreeMap<String, Value> = degrees
            .iter()
            .map(|&d| {
                let m_s = m.div_ceil(d);
                let mut pair = BTreeMap::new();
                if relu {
                    pair.insert(
                        "relu".to_string(),
                        Value::Str(format!("fc_m{m_s}_k{k}_relu")),
                    );
                }
                pair.insert("lin".to_string(), Value::Str(format!("fc_m{m_s}_k{k}_lin")));
                (d.to_string(), Value::Obj(pair))
            })
            .collect();
        obj(vec![
            ("name", Value::Str(name.into())),
            ("kind", Value::Str("fc".into())),
            ("k", Value::Num(0.0)),
            ("f", Value::Num(0.0)),
            ("s", Value::Num(1.0)),
            ("m", Value::Num(m as f64)),
            ("relu", Value::Bool(relu)),
            ("padding", Value::Str("SAME".into())),
            ("pool", Value::Num(0.0)),
            ("input_shape", usize_arr(&[k])),
            ("output_shape", usize_arr(&[m])),
            ("w_offset", Value::Num(w_offset as f64)),
            ("b_offset", Value::Num(b_offset as f64)),
            ("w_shape", usize_arr(&[m, k])),
            ("splits", Value::Obj(splits)),
        ])
    }

    fn write_file(path: &std::path::Path, bytes: &[u8]) -> Result<()> {
        std::fs::write(path, bytes)
            .map_err(|e| Error::io(path.display().to_string(), e))
    }

    fn f32_bytes(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    /// Shape of one synthetic two-layer MLP (the narrow default or the
    /// wide fleet-bench variant).
    struct MlpSpec {
        model: &'static str,
        fc1_m: usize,
        fc1_k: usize,
        fc2_m: usize,
        degrees1: &'static [usize],
        degrees2: &'static [usize],
    }

    const NARROW: MlpSpec = MlpSpec {
        model: MODEL,
        fc1_m: FC1_M,
        fc1_k: FC1_K,
        fc2_m: FC2_M,
        degrees1: &[1, 2, 4],
        degrees2: &[1, 2],
    };

    const WIDE: MlpSpec = MlpSpec {
        model: WIDE_MODEL,
        fc1_m: WIDE_M,
        fc1_k: WIDE_K,
        fc2_m: WIDE_M,
        degrees1: &WIDE_DEGREES,
        degrees2: &WIDE_DEGREES,
    };

    fn fresh_root(seed: u64) -> PathBuf {
        std::env::temp_dir().join(format!(
            "cdc-dnn-synth-{}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed),
            seed
        ))
    }

    /// Build a synthetic artifact set under a fresh temp directory.
    ///
    /// Layout mirrors `compile/aot.py`: `manifest.json`,
    /// `weights/mlp.bin`, `eval/images.bin`, `eval/labels.bin`. Weights
    /// and eval data are deterministic in `seed`.
    pub fn build(seed: u64) -> Result<SynthArtifacts> {
        build_at(fresh_root(seed), seed)
    }

    /// Build the synthetic artifact set at an explicit directory — the
    /// `cdc-dnn synth` CLI command, so binary entrypoints (serve,
    /// ablate) can run offline against a durable artifact path.
    pub fn build_at(root: impl Into<PathBuf>, seed: u64) -> Result<SynthArtifacts> {
        build_spec_at(root.into(), seed, &NARROW)
    }

    /// Build the *wide* synthetic artifact set ([`WIDE_MODEL`]: two
    /// 434-high fc layers with split degrees up to 62) under a fresh
    /// temp directory — the model the fleet-width transport bench
    /// shards across up to 64 loopback workers.
    pub fn build_wide(seed: u64) -> Result<SynthArtifacts> {
        build_spec_at(fresh_root(seed), seed, &WIDE)
    }

    fn build_spec_at(root: PathBuf, seed: u64, spec: &MlpSpec) -> Result<SynthArtifacts> {
        for sub in ["", "weights", "eval"] {
            let dir = root.join(sub);
            std::fs::create_dir_all(&dir)
                .map_err(|e| Error::io(dir.display().to_string(), e))?;
        }

        // ---- weights: fc1 (w, b) then fc2 (w, b), f32 LE -------------
        let mut rng = Pcg32::new(seed, 0x5e1f);
        let mut blob: Vec<f32> = Vec::new();
        let fc1_w_off = blob.len() * 4;
        blob.extend((0..spec.fc1_m * spec.fc1_k).map(|_| rng.normal() as f32 * 0.5));
        let fc1_b_off = blob.len() * 4;
        blob.extend((0..spec.fc1_m).map(|_| rng.normal() as f32 * 0.1));
        let fc2_w_off = blob.len() * 4;
        blob.extend((0..spec.fc2_m * spec.fc1_m).map(|_| rng.normal() as f32 * 0.5));
        let fc2_b_off = blob.len() * 4;
        blob.extend((0..spec.fc2_m).map(|_| rng.normal() as f32 * 0.1));
        let weights_file = format!("weights/{}.bin", spec.model);
        write_file(&root.join(&weights_file), &f32_bytes(&blob))?;

        // ---- eval set ------------------------------------------------
        let mut images: Vec<f32> = Vec::new();
        let mut labels: Vec<u8> = Vec::new();
        for i in 0..EVAL_COUNT {
            images.extend((0..spec.fc1_k).map(|_| rng.normal() as f32));
            labels.extend(((i % spec.fc2_m) as i32).to_le_bytes());
        }
        write_file(&root.join("eval/images.bin"), &f32_bytes(&images))?;
        write_file(&root.join("eval/labels.bin"), &labels)?;

        // ---- manifest ------------------------------------------------
        let mut artifacts = Vec::new();
        for &d in spec.degrees1 {
            for relu in [true, false] {
                artifacts.push(fc_artifact(spec.fc1_m.div_ceil(d), spec.fc1_k, relu).1);
            }
        }
        for &d in spec.degrees2 {
            artifacts.push(fc_artifact(spec.fc2_m.div_ceil(d), spec.fc1_m, false).1);
        }
        let model = obj(vec![
            ("name", Value::Str(spec.model.into())),
            ("input_shape", usize_arr(&[spec.fc1_k])),
            ("classes", Value::Num(spec.fc2_m as f64)),
            ("trained", Value::Bool(false)),
            ("weights_file", Value::Str(weights_file.clone())),
            (
                "layers",
                Value::Arr(vec![
                    fc_layer(
                        "fc1",
                        spec.fc1_m,
                        spec.fc1_k,
                        true,
                        fc1_w_off,
                        fc1_b_off,
                        spec.degrees1,
                    ),
                    fc_layer(
                        "fc2",
                        spec.fc2_m,
                        spec.fc1_m,
                        false,
                        fc2_w_off,
                        fc2_b_off,
                        spec.degrees2,
                    ),
                ]),
            ),
        ]);
        let manifest = obj(vec![
            ("artifacts", Value::Arr(artifacts)),
            ("models", Value::Arr(vec![model])),
            (
                "eval_set",
                obj(vec![
                    ("images", Value::Str("eval/images.bin".into())),
                    ("labels", Value::Str("eval/labels.bin".into())),
                    ("count", Value::Num(EVAL_COUNT as f64)),
                    ("image_shape", usize_arr(&[spec.fc1_k])),
                ]),
            ),
            ("goldens", Value::Arr(Vec::new())),
        ]);
        write_file(
            &root.join("manifest.json"),
            manifest.to_string_pretty().as_bytes(),
        )?;
        Ok(SynthArtifacts { root })
    }
}

/// Generator helpers.
pub mod gen {
    use crate::rng::Pcg32;

    /// Uniform usize in [lo, hi].
    pub fn usize_in(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// A vector of finite arrival times with `n_inf` entries set to ∞ at
    /// random positions — the canonical "arrivals with failures" input.
    pub fn arrivals(rng: &mut Pcg32, n: usize, n_inf: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1000.0)).collect();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        for &i in idx.iter().take(n_inf) {
            v[i] = f64::INFINITY;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            1,
            50,
            |rng| rng.below(100),
            |&x| if x < 100 { Ok(()) } else { Err("out of range".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(2, 50, |rng| rng.below(10), |&x| {
            if x != 7 {
                Ok(())
            } else {
                Err("hit 7".into())
            }
        });
    }

    #[test]
    fn arrivals_have_requested_failures() {
        let mut rng = crate::rng::Pcg32::seeded(3);
        let a = gen::arrivals(&mut rng, 10, 3);
        assert_eq!(a.iter().filter(|t| t.is_infinite()).count(), 3);
    }
}
