//! Coordinator-side TCP transport: persistent per-device connections
//! multiplexed through the single [`evloop`] I/O thread.
//!
//! The coordinator's I/O cost is **O(1) in fleet width**: however many
//! workers a session spans, exactly one `tcp-evloop` thread owns every
//! socket ([`TcpTransport::IO_THREADS`]). Handle methods (`dispatch`,
//! `deploy`, …) encode frames, queue them per device, and wake the
//! loop; the loop batches each round's frames into one `writev` flush
//! per connection and parses replies in place out of per-connection
//! receive buffers (DESIGN.md §12).
//!
//! ## Liveness invariant
//!
//! The serve engine blocks until every dispatched task has a
//! completion. Over real sockets three things can eat a reply: a slow
//! worker (straggler), a worker that died mid-request (SIGKILL), and a
//! write into a dead connection. Each is converted into a synthesised
//! lost completion (`result: None`, `t_arrival = ∞`) — exactly the
//! shape the simulator delivers for a dropped reply, so the policy /
//! CDC-recovery layers run unchanged:
//!
//! * **deadline reaping**: every dispatched task carries a wall-clock
//!   deadline (`TcpConfig::order_deadline_ms` after dispatch); the
//!   event loop uses the earliest deadline as its poll timeout and
//!   reaps overdue tasks when it fires. This is the straggler gate on
//!   real time — CDC then substitutes the parity result without
//!   waiting, the paper's zero-latency recovery.
//! * **connection death**: EOF or a socket error on the loop marks the
//!   device dead and synthesises losses for everything outstanding on
//!   it — a killed worker process is detected at TCP speed, not at the
//!   deadline.
//! * **dispatch to a dead device**: synthesises losses immediately
//!   (mirrors the simulator, where a failed device still "answers"
//!   with `∞`).
//!
//! Late replies that arrive after their task was reaped are dropped on
//! the loop (the task is no longer outstanding), so a task never
//! yields two completions.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::net::UnixStream;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::fleet::{Completion, FailurePlan, NetConfig, TaskDef, WorkOrder};

use super::evloop::{self, lock, OutTask, Shared};
use super::wire::{self, Frame};
use super::{MembershipEvent, TcpConfig, Transport};

/// Real-execution transport over per-device TCP connections.
pub struct TcpTransport {
    shared: Arc<Shared>,
    rx: Receiver<Completion>,
    evloop: Option<JoinHandle<()>>,
    deadline_ms: f64,
    listen_addr: Option<String>,
}

impl TcpTransport {
    /// Coordinator I/O threads, independent of fleet width: one event
    /// loop owns every connection. The fleet-width bench asserts this
    /// O(1) property as the width sweep grows.
    pub const IO_THREADS: usize = 1;

    /// Connect to the first `n_devices` workers of `cfg.workers`,
    /// handshake each, then hand every socket to the event loop.
    pub fn connect(cfg: &TcpConfig, n_devices: usize, seed: u64) -> Result<TcpTransport> {
        if cfg.workers.len() < n_devices {
            return Err(Error::Config(format!(
                "tcp transport: {} worker address(es) for {} devices \
                 (data + redundancy) — start more workers or list more \
                 addresses in the deployment's transport section",
                cfg.workers.len(),
                n_devices
            )));
        }
        let timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));
        // Connect + handshake every worker up front, blocking, on the
        // caller thread: a failure here just drops the already-open
        // sockets (workers return to their accept loop) — no I/O
        // thread exists yet.
        let mut streams = Vec::with_capacity(n_devices);
        for (device, addr) in cfg.workers.iter().take(n_devices).enumerate() {
            let stream = connect_one(addr, timeout)?;
            stream
                .set_nodelay(true)
                .map_err(|e| Error::Wire(format!("{addr}: set_nodelay: {e}")))?;
            // Handshake under a read timeout so a wedged worker fails
            // fast; cleared before the event loop takes the socket
            // nonblocking.
            stream
                .set_read_timeout(Some(timeout))
                .map_err(|e| Error::Wire(format!("{addr}: set timeout: {e}")))?;
            let mut hs = &stream;
            wire::write_frame(&mut hs, &wire::hello(seed, device as u32))?;
            match wire::read_frame(&mut hs)? {
                Some(Frame::HelloAck { proto }) if wire::proto_compatible(proto) => {}
                Some(Frame::HelloAck { proto }) => {
                    return Err(wire::proto_mismatch(
                        &format!("worker {addr}"),
                        "this coordinator",
                        proto,
                    ))
                }
                other => {
                    return Err(Error::Wire(format!(
                        "{addr}: bad handshake reply: {other:?}"
                    )))
                }
            }
            stream
                .set_read_timeout(None)
                .map_err(|e| Error::Wire(format!("{addr}: clear timeout: {e}")))?;
            streams.push(stream);
        }

        // Live-membership listener: joining workers dial this port and
        // `Register` at any time. `listen: None` freezes the fleet.
        let (listener, listen_addr) = match &cfg.listen {
            Some(bind) => {
                let l = TcpListener::bind(bind)
                    .map_err(|e| Error::Wire(format!("join listener {bind}: bind: {e}")))?;
                let addr = l
                    .local_addr()
                    .map_err(|e| Error::Wire(format!("join listener {bind}: local_addr: {e}")))?;
                (Some(l), Some(addr.to_string()))
            }
            None => (None, None),
        };

        let (tx, rx) = channel();
        let (wake_tx, wake_rx) =
            UnixStream::pair().map_err(|e| Error::Wire(format!("wake pipe: {e}")))?;
        wake_tx
            .set_nonblocking(true)
            .map_err(|e| Error::Wire(format!("wake pipe: {e}")))?;
        let shared = Arc::new(Shared::new(n_devices, seed, cfg, tx, wake_tx));
        let evloop = evloop::spawn(streams, shared.clone(), wake_rx, listener)?;
        Ok(TcpTransport {
            shared,
            rx,
            evloop: Some(evloop),
            deadline_ms: cfg.order_deadline_ms.max(1.0),
            listen_addr,
        })
    }

    /// Number of I/O threads this transport runs — always
    /// [`TcpTransport::IO_THREADS`], whatever the fleet width.
    pub fn io_threads(&self) -> usize {
        TcpTransport::IO_THREADS
    }

    /// Per-device liveness snapshot (tests / diagnostics), covering
    /// every slot assigned so far (initial fleet + admitted joiners).
    pub fn alive(&self) -> Vec<bool> {
        let width = self.shared.width();
        let mut v = lock(&self.shared.state).alive.clone();
        v.truncate(width);
        v
    }

    fn check_device(&self, device: usize) -> Result<()> {
        if device >= self.shared.width() {
            return Err(Error::Config(format!("no device {device}")));
        }
        Ok(())
    }

    fn device_alive(&self, device: usize) -> bool {
        lock(&self.shared.state).alive[device]
    }
}

impl Transport for TcpTransport {
    fn label(&self) -> &'static str {
        "tcp"
    }

    fn wall_clock(&self) -> bool {
        true
    }

    fn now_ms(&self) -> f64 {
        self.shared.now_ms()
    }

    fn begin_serve(&self) {
        // Orphans of a previous serve (late replies, reaped stragglers)
        // must not leak into this run's gather loop or deadlines.
        {
            let mut st = lock(&self.shared.state);
            st.outstanding.clear();
        }
        while self.rx.try_recv().is_ok() {}
        *lock(&self.shared.epoch) = std::time::Instant::now();
    }

    fn pace(&self, t_ms: f64) {
        let now = self.now_ms();
        if t_ms > now {
            std::thread::sleep(Duration::from_secs_f64((t_ms - now) / 1e3));
        }
    }

    fn clamp_ms(&self, t_ms: f64) -> f64 {
        t_ms.max(self.now_ms())
    }

    fn n_devices(&self) -> usize {
        // Grows as joiners register: the serve engine sizes its
        // per-device tables off this and re-checks after every
        // membership application.
        self.shared.width()
    }

    fn deploy(&self, device: usize, tasks: Vec<TaskDef>) -> Result<()> {
        self.check_device(device)?;
        if !self.device_alive(device) {
            return Err(Error::Fleet(format!("device {device} is gone")));
        }
        // One frame per task so a device's whole shard set can exceed
        // the frame cap without tripping it; a single shard that still
        // does gets a diagnosis *before* encoding (the encoder asserts
        // the cap). The frames queue as one batch — a single wake, one
        // coalesced flush. A mid-deploy socket failure surfaces as
        // connection death: the affected tasks' dispatches later
        // resolve as synthesised losses.
        for task in &tasks {
            let wbytes = match &task.quant {
                Some(q) => q.bytes(),
                None => 4 * task.w.len(),
            };
            let payload = wbytes + 4 * task.b.len() + task.artifact.len() + 128;
            if payload > wire::MAX_FRAME_LEN as usize {
                return Err(Error::Config(format!(
                    "task {}: ~{payload} bytes of weights exceed the wire frame \
                     cap ({} bytes) — split the layer over more devices",
                    task.id,
                    wire::MAX_FRAME_LEN
                )));
            }
            let frame = wire::deploy(std::slice::from_ref(task));
            lock(&self.shared.outq[device]).push_back(frame);
        }
        self.shared.wake();
        Ok(())
    }

    fn undeploy(&self, device: usize, task_ids: Vec<u64>) -> Result<()> {
        self.check_device(device)?;
        // Best effort: undeploying from a dead device is a no-op.
        if self.device_alive(device) {
            self.shared.enqueue(device, wire::undeploy(&task_ids));
        }
        Ok(())
    }

    fn dispatch(&self, device: usize, order: WorkOrder) -> Result<()> {
        self.check_device(device)?;
        let deadline_ms = self.now_ms() + self.deadline_ms;
        {
            let mut st = lock(&self.shared.state);
            if !st.alive[device] {
                // A dead device still "answers": synthesised losses keep
                // the gather loop's completion count exact.
                drop(st);
                for &t in &order.tasks {
                    self.shared.send_lost(order.req, t, device);
                }
                return Ok(());
            }
            // Register before the frame can possibly leave, so a reply
            // can never race its own bookkeeping.
            for &t in &order.tasks {
                st.outstanding.insert((order.req, t), OutTask { device, deadline_ms });
            }
        }
        let frame =
            wire::work(order.req, &order.tasks, order.batch, order.input.as_ref());
        // If the connection dies before the flush, mark_dead reaps the
        // tasks registered above — dispatch still succeeds from the
        // engine's point of view (the losses are in the stream).
        self.shared.enqueue(device, frame);
        Ok(())
    }

    fn recv(&self) -> Result<Completion> {
        self.rx
            .recv()
            .map_err(|_| Error::Fleet("tcp completion channel closed".into()))
    }

    fn recv_deadline(&self, until_ms: f64) -> Result<Option<Completion>> {
        let now = self.now_ms();
        if until_ms <= now {
            return Ok(self.rx.try_recv().ok());
        }
        let dur = Duration::from_secs_f64((until_ms - now) / 1e3);
        match self.rx.recv_timeout(dur) {
            Ok(c) => Ok(Some(c)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Fleet("tcp completion channel closed".into()))
            }
        }
    }

    fn try_recv(&self) -> Option<Completion> {
        self.rx.try_recv().ok()
    }

    fn reclaim(&self, buf: Vec<f32>) -> Option<Vec<f32>> {
        // Shard outputs were decoded into arena buffers on the event
        // loop; handing them back closes the receive path's allocation
        // cycle (DESIGN.md §12 lifetimes).
        lock(&self.shared.arena).put(buf);
        None
    }

    fn set_failure(&self, device: usize, plan: FailurePlan) -> Result<()> {
        self.check_device(device)?;
        if self.device_alive(device) {
            self.shared.enqueue(device, wire::set_failure(&plan));
        }
        Ok(())
    }

    fn set_net(&self, device: usize, net: NetConfig) -> Result<()> {
        self.check_device(device)?;
        if self.device_alive(device) {
            self.shared.enqueue(device, wire::set_net(true, &net));
        }
        Ok(())
    }

    fn set_rate(&self, device: usize, macs_per_ms: f64) -> Result<()> {
        self.check_device(device)?;
        if self.device_alive(device) {
            self.shared.enqueue(device, wire::set_rate(macs_per_ms));
        }
        Ok(())
    }

    fn poll_membership(&self) -> Vec<MembershipEvent> {
        self.shared.take_events()
    }

    fn listen_addr(&self) -> Option<String> {
        self.listen_addr.clone()
    }

    fn retire(&self, device: usize) {
        if device < self.shared.width() {
            self.shared.retire(device);
        }
    }

    fn counters(&self) -> Vec<(&'static str, u64)> {
        let net = &self.shared.net;
        let rel = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);
        // Sum the per-device worker snapshots (cumulative per session;
        // a dead worker's last snapshot keeps counting, which is the
        // right monotone behaviour for a Prometheus counter).
        let mut worker = [0u64; wire::WCTR_SLOTS];
        for slot in lock(&self.shared.worker_counters).iter() {
            for (acc, v) in worker.iter_mut().zip(slot) {
                *acc += v;
            }
        }
        vec![
            ("net_tx_bytes_total", rel(&net.bytes_tx)),
            ("net_rx_bytes_total", rel(&net.bytes_rx)),
            ("net_tx_frames_total", rel(&net.frames_tx)),
            ("net_rx_frames_total", rel(&net.frames_rx)),
            ("net_writev_calls_total", rel(&net.writev_calls)),
            ("transport_reaped_tasks_total", rel(&net.reaped_tasks)),
            ("transport_heartbeats_sent_total", rel(&net.heartbeats_sent)),
            ("fleet_joins_total", rel(&net.joins)),
            ("fleet_deaths_total", rel(&net.deaths)),
            ("fleet_suspects_total", rel(&net.suspects)),
            ("fleet_leaves_total", rel(&net.leaves)),
            ("worker_orders_total", worker[wire::WCTR_ORDERS as usize]),
            ("worker_replies_total", worker[wire::WCTR_REPLIES as usize]),
            ("worker_dropped_replies_total", worker[wire::WCTR_DROPPED as usize]),
            ("worker_exec_errors_total", worker[wire::WCTR_EXEC_ERRORS as usize]),
        ]
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake();
        // The loop does a final best-effort flush and shuts every
        // socket down; workers return to their accept loop (they are
        // NOT shut down — the loopback harness owns child lifetimes,
        // and standalone workers keep serving the next coordinator).
        if let Some(t) = self.evloop.take() {
            let _ = t.join();
        }
    }
}

fn connect_one(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let mut last = Error::Wire(format!("{addr}: no addresses resolved"));
    let resolved = addr
        .to_socket_addrs()
        .map_err(|e| Error::Wire(format!("{addr}: resolve: {e}")))?;
    for sa in resolved {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Error::Wire(format!("{addr}: connect: {e}")),
        }
    }
    Err(last)
}
