//! Crate-wide error type (hand-rolled: the crate builds offline with no
//! external dependencies, so no `thiserror`).

use std::fmt;

/// Errors surfaced by the cdc-dnn library.
#[derive(Debug)]
pub enum Error {
    /// Malformed or missing artifact manifest / weights / goldens.
    Artifact(String),
    /// JSON parse error (line/col best-effort).
    Json(String),
    /// Shape mismatch in tensor ops or executor inputs.
    Shape(String),
    /// Underlying XLA/PJRT (or interpreter-backend) failure.
    Xla(String),
    /// Invalid deployment / partition configuration.
    Config(String),
    /// Fleet communication failure (device hung up, channel closed).
    Fleet(String),
    /// Transport wire-protocol failure (malformed frame, socket error,
    /// handshake mismatch) — see `transport::wire`.
    Wire(String),
    /// IO error with path context.
    Io {
        path: String,
        source: std::io::Error,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Fleet(m) => write!(f, "fleet error: {m}"),
            Error::Wire(m) => write!(f, "wire error: {m}"),
            Error::Io { path, source } => write!(f, "io error: {path}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Wrap an io::Error with the offending path.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
