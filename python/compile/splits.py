"""Model-parallel splitting methods and CDC parity construction (paper §4-5).

Implements, at the matrix level of Section 5.1, the five distribution
methods of Section 4 and the CDC weight coding of Sections 5.2-5.3:

  fc:    output splitting   (divides W rows + output — CDC-suitable)
         input splitting    (divides W cols + input  — NOT suitable)
  conv:  channel splitting  (divides filter-matrix rows — CDC-suitable)
         spatial splitting  (divides unrolled-input cols — NOT suitable)
         filter splitting   (divides both depth-wise     — NOT suitable)

Table 1 of the paper is reproduced by :data:`SUITABILITY`; the rust
`partition` module mirrors this logic and a golden-manifest test keeps the
two in sync.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


def balanced_ranges(total: int, parts: int) -> List[Tuple[int, int]]:
    """Split ``range(total)`` into ``parts`` contiguous ranges whose sizes
    differ by at most one — the paper's balanced work assignment."""
    if parts <= 0:
        raise ValueError("parts must be positive")
    base, extra = divmod(total, parts)
    ranges, start = [], 0
    for i in range(parts):
        size = base + (1 if i < extra else 0)
        ranges.append((start, start + size))
        start += size
    return ranges


@dataclasses.dataclass(frozen=True)
class Shard:
    """One device's task: a GEMM over a slice of the layer's weight/input.

    ``rows``/``cols`` describe which slice of the *full* weight matrix this
    shard owns (rows ⇒ output split / channel split; cols ⇒ input split /
    filter split). ``is_parity`` marks the CDC device of Eq. 11.
    """

    device: int
    w: np.ndarray          # (m_s, k_s) weight slice (zero-padded if needed)
    b: Optional[np.ndarray]  # (m_s,) bias slice or None
    rows: Tuple[int, int]  # row range [lo, hi) in the full W
    cols: Tuple[int, int]  # col range [lo, hi) in the full W
    is_parity: bool = False
    covers: Tuple[int, ...] = ()  # data-shard devices a parity shard protects


def _pad_rows(w: np.ndarray, rows: int) -> np.ndarray:
    if w.shape[0] == rows:
        return w
    return np.pad(w, ((0, rows - w.shape[0]), (0, 0)))


def output_split(w: np.ndarray, b: Optional[np.ndarray], n_dev: int,
                 *, uniform: bool = True) -> List[Shard]:
    """fc output splitting (Fig. 6): W rows divided among devices.

    With ``uniform=True`` every shard is zero-padded to the max shard height
    so the CDC parity (an elementwise sum of shards, Eq. 11) is well formed;
    the padding rows compute zeros and are dropped at merge.
    """
    m, k = w.shape
    ranges = balanced_ranges(m, n_dev)
    max_rows = max(hi - lo for lo, hi in ranges)
    shards = []
    for dev, (lo, hi) in enumerate(ranges):
        ws = w[lo:hi]
        bs = b[lo:hi] if b is not None else None
        if uniform:
            ws = _pad_rows(ws, max_rows)
            if bs is not None:
                bs = np.pad(bs, (0, max_rows - (hi - lo)))
        shards.append(Shard(dev, ws, bs, (lo, hi), (0, k)))
    return shards


def input_split(w: np.ndarray, b: Optional[np.ndarray], n_dev: int) -> List[Shard]:
    """fc input splitting (Fig. 7): W cols + input divided; devices emit
    partial sums over the *whole* output. Bias/σ applied after aggregation,
    so shards carry no bias. Not CDC-suitable (paper Eq. 13-14)."""
    m, k = w.shape
    shards = []
    for dev, (lo, hi) in enumerate(balanced_ranges(k, n_dev)):
        shards.append(Shard(dev, w[:, lo:hi], None, (0, m), (lo, hi)))
    return shards


def channel_split(wmat: np.ndarray, b: Optional[np.ndarray], n_dev: int,
                  *, uniform: bool = True) -> List[Shard]:
    """conv channel splitting (Fig. 8): identical in matrix form to fc
    output splitting but over the unrolled (K, F²C) filter matrix."""
    return output_split(wmat, b, n_dev, uniform=uniform)


def spatial_split_ranges(out_hw: Tuple[int, int], n_dev: int) -> List[Tuple[int, int]]:
    """conv spatial splitting (Fig. 9): divide the unrolled-input columns
    (== output pixels, row-major) among devices. Each device needs the full
    filter matrix; merge is a column concat. Not CDC-suitable."""
    oh, ow = out_hw
    return balanced_ranges(oh * ow, n_dev)


def filter_split(wmat: np.ndarray, n_dev: int) -> List[Shard]:
    """conv filter splitting (Fig. 10): depth-wise division of both filter
    matrix columns and unrolled-input rows; outer-product style partial
    sums. Not CDC-suitable."""
    m, k = wmat.shape
    shards = []
    for dev, (lo, hi) in enumerate(balanced_ranges(k, n_dev)):
        shards.append(Shard(dev, wmat[:, lo:hi], None, (0, m), (lo, hi)))
    return shards


def cdc_parity_shard(shards: List[Shard], *, covers: Optional[List[int]] = None,
                     device: Optional[int] = None) -> Shard:
    """Build the CDC parity shard (Eq. 11) over ``covers`` data shards.

    The parity weights are the elementwise sum of the covered shards'
    (uniform-height) weights — computed offline, input-independent. The
    parity bias is likewise the sum, so parity output = Σ (W_d x + b_d),
    and a missing device's *pre-activation* output is recovered by plain
    subtraction. (Shards must therefore run with the activation deferred to
    the merge point when CDC is enabled; see ``aot.py``.)
    """
    covered = shards if covers is None else [shards[i] for i in covers]
    if not covered:
        raise ValueError("parity must cover at least one shard")
    hts = {s.w.shape for s in covered}
    if len(hts) != 1:
        raise ValueError(f"covered shards must be uniform, got {hts}")
    if any(s.is_parity for s in covered):
        raise ValueError("parity-of-parity is not supported")
    w = np.sum([s.w for s in covered], axis=0)
    b = None
    if covered[0].b is not None:
        b = np.sum([s.b for s in covered], axis=0)
    return Shard(
        device=len(shards) if device is None else device,
        w=w,
        b=b,
        rows=(-1, -1),
        cols=covered[0].cols,
        is_parity=True,
        covers=tuple(s.device for s in covered),
    )


def cdc_decode(parity_out: np.ndarray, received: List[np.ndarray]) -> np.ndarray:
    """Recover the single missing shard output: parity − Σ received."""
    out = parity_out.copy()
    for r in received:
        out -= r
    return out


def multi_parity_shards(shards: List[Shard], group_size: int) -> List[Shard]:
    """Fig. 18: multiple parity devices, each summing a *group* of shards.

    With groups of ``group_size`` the system tolerates one failure per
    group — e.g. 4 data shards with group_size=2 gives two parity devices
    and tolerance to two failures (one in each half). ``group_size ==
    len(shards)`` degenerates to the single-parity scheme.
    """
    data = [s for s in shards if not s.is_parity]
    parities = []
    for gi, (lo, hi) in enumerate(
        balanced_ranges(len(data), -(-len(data) // group_size))
    ):
        parities.append(
            cdc_parity_shard(data, covers=list(range(lo, hi)),
                             device=len(data) + gi)
        )
    return parities


# ---------------------------------------------------------------------------
# Table 1 — Distribution Techniques Suitable for Robustness.
# (layer, method) -> (divides_input, divides_weight, divides_output, suitable)
SUITABILITY = {
    ("fc", "output"): (False, True, True, True),
    ("fc", "input"): (True, True, False, False),
    ("conv", "channel"): (False, True, True, True),
    ("conv", "spatial"): (True, False, True, False),
    ("conv", "filter"): (True, True, True, False),
}


def is_cdc_suitable(layer: str, method: str) -> bool:
    """A method admits library-level CDC iff it divides the weights *without*
    dividing the input (paper §5.3): parity weights can then be summed
    offline. Methods that divide the input would need runtime input sums
    (2× compute) — no better than modular redundancy."""
    din, dw, _dout, suitable = SUITABILITY[(layer, method)]
    assert suitable == (dw and not din)
    return suitable
