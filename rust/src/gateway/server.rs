//! Gateway HTTP event loop: nonblocking accept + per-connection buffer
//! state machines on the shared `Poller` readiness core, one thread for
//! the whole front door.
//!
//! Data path: readable bytes append to a per-connection read buffer; the
//! incremental parser lifts at most one request at a time off the front.
//! Local routes (`/v1/healthz`, 404/405, malformed bodies) answer inline.
//! Pipeline routes become [`GatewayCmd`] values sent to the serve loop and
//! the connection is *parked* — parsing pauses (no pipelined request can
//! overtake its predecessor's reply) until the serve loop answers through
//! the reply channel + `UnixStream` waker, or the park deadline passes and
//! the client gets a 504.
//!
//! Hardening mirrors `transport::wire`: the read buffer is capped at
//! head-cap + body-cap + slack, every parse failure is a typed status (the
//! connection is answered then closed), and a dead client never wedges the
//! loop — replies to vanished connections are simply dropped.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::tensor::Tensor;
use crate::transport::evloop::{PollEvent, Poller};

use super::http::{self, Parsed, Request};
use super::{error_body, GatewayCmd, GatewayConfig, HttpReply, Responder};

const TOKEN_LISTEN: u64 = u64::MAX - 1;
const TOKEN_WAKE: u64 = u64::MAX;
/// Idle poll tick: bounds how late a park-deadline sweep can run.
const TICK: Duration = Duration::from_millis(200);

/// What the HTTP thread needs to know about the deployment to validate
/// `POST /v1/infer` bodies before they ever reach the pipeline.
#[derive(Debug, Clone)]
pub struct ServerCtx {
    pub model: String,
    pub input_len: usize,
    /// The session's telemetry registry (DESIGN.md §16), shared with
    /// the serve loop: `GET /metrics` and `GET /v1/traces` render from
    /// it right here on the HTTP thread — no pipeline round-trip, so
    /// scrapes keep answering even while the serve loop is saturated.
    pub telemetry: Arc<crate::telemetry::Telemetry>,
}

/// The dashboard page (`GET /`): a single self-contained HTML file —
/// no external scripts, styles, or fonts — polling the gateway's own
/// JSON + Prometheus endpoints.
const DASHBOARD_HTML: &str = include_str!("dashboard.html");

/// Handle to the running HTTP front door. Dropping it stops the thread.
pub struct GatewayServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Arc<UnixStream>,
    handle: Option<JoinHandle<()>>,
}

impl GatewayServer {
    /// Bind `cfg.listen`, spawn the event-loop thread, and return once the
    /// socket is accepting. `cmd_tx` feeds the live serve loop.
    pub fn start(
        cfg: &GatewayConfig,
        ctx: ServerCtx,
        cmd_tx: Sender<GatewayCmd>,
    ) -> Result<GatewayServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| Error::Wire(format!("gateway bind {}: {e}", cfg.listen)))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Wire(format!("gateway set_nonblocking: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Wire(format!("gateway local_addr: {e}")))?;
        let (wake_rx, wake_tx) = UnixStream::pair()
            .map_err(|e| Error::Wire(format!("gateway waker pair: {e}")))?;
        // Both ends nonblocking: the read end lives on the poller; the
        // write end must never block a responder even if the pipe fills
        // (a pending byte already means the loop will wake).
        wake_rx
            .set_nonblocking(true)
            .map_err(|e| Error::Wire(format!("gateway waker nonblocking: {e}")))?;
        wake_tx
            .set_nonblocking(true)
            .map_err(|e| Error::Wire(format!("gateway waker nonblocking: {e}")))?;
        let waker = Arc::new(wake_tx);
        let stop = Arc::new(AtomicBool::new(false));

        let mut lp = Loop::new(
            listener,
            wake_rx,
            cfg.clone(),
            ctx,
            cmd_tx,
            waker.clone(),
            stop.clone(),
        )?;
        let handle = std::thread::Builder::new()
            .name("gateway-http".to_string())
            .spawn(move || lp.run())
            .map_err(|e| Error::Wire(format!("gateway thread spawn: {e}")))?;

        Ok(GatewayServer { addr, stop, waker, handle: Some(handle) })
    }

    /// The bound socket address (ephemeral port already resolved).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Convenience `http://host:port` base URL.
    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }
}

impl Drop for GatewayServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = (&*self.waker).write(&[1u8]);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A routed request waiting on the serve loop.
struct Parked {
    seq: u64,
    deadline: Instant,
    keep_alive: bool,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    woff: usize,
    want_write: bool,
    parked: Option<Parked>,
    next_seq: u64,
    close_after_flush: bool,
}

impl Conn {
    fn queue(&mut self, bytes: Vec<u8>) {
        if self.woff > 0 && self.woff == self.wbuf.len() {
            self.wbuf.clear();
            self.woff = 0;
        }
        self.wbuf.extend_from_slice(&bytes);
    }

    fn queue_json(&mut self, status: u16, body: &Value, keep_alive: bool) {
        let payload = body.to_string_compact();
        self.queue_raw(status, "application/json", payload.as_bytes(), keep_alive);
    }

    /// Queue a response with an arbitrary content type (Prometheus text,
    /// the dashboard HTML, Chrome trace JSON).
    fn queue_raw(&mut self, status: u16, content_type: &str, body: &[u8], keep_alive: bool) {
        self.queue(http::response(status, content_type, body, keep_alive));
        if !keep_alive {
            self.close_after_flush = true;
        }
    }

    /// Flush as much as the socket accepts. Returns false when the
    /// connection should be dropped (fatal write error).
    fn flush(&mut self) -> bool {
        while self.woff < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.woff..]) {
                Ok(0) => return false,
                Ok(n) => self.woff += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.woff == self.wbuf.len() {
            self.wbuf.clear();
            self.woff = 0;
            if self.close_after_flush {
                return false;
            }
        }
        true
    }
}

/// What the router decided about one parsed request.
enum Routed {
    /// Answer from the HTTP thread, no pipeline involved.
    Now(u16, Value),
    /// Answer from the HTTP thread with a non-JSON payload (`/metrics`
    /// exposition text, the dashboard page).
    Raw(u16, &'static str, Vec<u8>),
    /// Forward to the serve loop and park the connection.
    Cmd(CmdSpec),
}

struct Loop {
    poller: Poller,
    listener: TcpListener,
    wake_rx: UnixStream,
    cfg: GatewayConfig,
    ctx: ServerCtx,
    cmd_tx: Sender<GatewayCmd>,
    reply_tx: Sender<HttpReply>,
    reply_rx: Receiver<HttpReply>,
    waker: Arc<UnixStream>,
    stop: Arc<AtomicBool>,
    conns: BTreeMap<u64, Conn>,
    next_token: u64,
}

impl Loop {
    #[allow(clippy::too_many_arguments)]
    fn new(
        listener: TcpListener,
        wake_rx: UnixStream,
        cfg: GatewayConfig,
        ctx: ServerCtx,
        cmd_tx: Sender<GatewayCmd>,
        waker: Arc<UnixStream>,
        stop: Arc<AtomicBool>,
    ) -> Result<Loop> {
        let poller = Poller::new()?;
        poller.add(listener.as_raw_fd(), TOKEN_LISTEN, false)?;
        poller.add(wake_rx.as_raw_fd(), TOKEN_WAKE, false)?;
        let (reply_tx, reply_rx) = channel();
        Ok(Loop {
            poller,
            listener,
            wake_rx,
            cfg,
            ctx,
            cmd_tx,
            reply_tx,
            reply_rx,
            waker,
            stop,
            conns: BTreeMap::new(),
            next_token: 0,
        })
    }

    fn run(&mut self) {
        let mut events: Vec<PollEvent> = Vec::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            let timeout = self.next_deadline().map_or(TICK, |d| {
                d.saturating_duration_since(Instant::now()).min(TICK)
            });
            if self.poller.wait(&mut events, Some(timeout)).is_err() {
                return;
            }
            for i in 0..events.len() {
                let (token, readable, writable, hangup) = {
                    let e = &events[i];
                    (e.token, e.readable, e.writable, e.hangup)
                };
                match token {
                    TOKEN_WAKE => self.drain_waker(),
                    TOKEN_LISTEN => self.accept_ready(),
                    t => self.conn_ready(t, readable, writable, hangup),
                }
            }
            self.drain_replies();
            self.sweep_deadlines();
        }
    }

    /// Earliest park deadline across connections, if any.
    fn next_deadline(&self) -> Option<Instant> {
        self.conns
            .values()
            .filter_map(|c| c.parked.as_ref().map(|p| p.deadline))
            .min()
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    if self.poller.add(stream.as_raw_fd(), token, false).is_err() {
                        continue;
                    }
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            woff: 0,
                            want_write: false,
                            parked: None,
                            next_seq: 0,
                            close_after_flush: false,
                        },
                    );
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn conn_ready(&mut self, token: u64, readable: bool, writable: bool, hangup: bool) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        let mut dead = false;
        if readable || hangup {
            let mut buf = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&buf[..n]);
                        // Absolute backstop: head cap + body cap + slack.
                        let cap = http::MAX_HEAD_BYTES + self.cfg.max_body_bytes + 4096;
                        if conn.rbuf.len() > cap {
                            self.ctx.telemetry.gateway_errors_total.inc();
                            conn.queue_json(
                                413,
                                &error_body("request exceeds gateway buffer cap"),
                                false,
                            );
                            conn.rbuf.clear();
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
        }
        if dead {
            self.close(token);
            return;
        }
        if writable || readable || hangup {
            self.advance(token);
        }
    }

    /// Parse + route as many requests as the parked-state allows, then
    /// flush and fix up write interest. Closes the connection on fatal IO.
    fn advance(&mut self, token: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.parked.is_some() || conn.close_after_flush {
                break;
            }
            match http::parse_request(&conn.rbuf, self.cfg.max_body_bytes) {
                Ok(Parsed::Partial) => break,
                Ok(Parsed::Complete { req, consumed }) => {
                    conn.rbuf.drain(..consumed);
                    let seq = conn.next_seq;
                    conn.next_seq += 1;
                    self.ctx.telemetry.gateway_requests_total.inc();
                    match route(&req, &self.ctx) {
                        Routed::Now(status, body) => {
                            if status >= 400 {
                                self.ctx.telemetry.gateway_errors_total.inc();
                            }
                            conn.queue_json(status, &body, req.keep_alive)
                        }
                        Routed::Raw(status, content_type, body) => {
                            if status >= 400 {
                                self.ctx.telemetry.gateway_errors_total.inc();
                            }
                            conn.queue_raw(status, content_type, &body, req.keep_alive)
                        }
                        Routed::Cmd(spec) => {
                            let resp = Responder::new(
                                token,
                                seq,
                                self.reply_tx.clone(),
                                self.waker.clone(),
                            );
                            let cmd = attach(spec, resp);
                            if self.cmd_tx.send(cmd).is_err() {
                                self.ctx.telemetry.gateway_errors_total.inc();
                                let conn = self.conns.get_mut(&token).unwrap();
                                conn.queue_json(
                                    503,
                                    &error_body("serve loop is not running"),
                                    false,
                                );
                            } else {
                                let deadline = Instant::now()
                                    + Duration::from_millis(self.cfg.request_timeout_ms);
                                let conn = self.conns.get_mut(&token).unwrap();
                                conn.parked =
                                    Some(Parked { seq, deadline, keep_alive: req.keep_alive });
                            }
                        }
                    }
                }
                Err(e) => {
                    self.ctx.telemetry.gateway_requests_total.inc();
                    self.ctx.telemetry.gateway_errors_total.inc();
                    conn.queue_json(e.status, &error_body(e.msg.clone()), false);
                    conn.rbuf.clear();
                    break;
                }
            }
        }
        self.flush_and_rearm(token);
    }

    fn flush_and_rearm(&mut self, token: u64) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if !conn.flush() {
            self.close(token);
            return;
        }
        let want = conn.woff < conn.wbuf.len();
        if want != conn.want_write {
            conn.want_write = want;
            let fd = conn.stream.as_raw_fd();
            if self.poller.rearm(fd, token, want).is_err() {
                self.close(token);
            }
        }
    }

    fn drain_replies(&mut self) {
        loop {
            let reply = match self.reply_rx.try_recv() {
                Ok(r) => r,
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => return,
            };
            let Some(conn) = self.conns.get_mut(&reply.conn) else { continue };
            let Some(parked) = conn.parked.take() else { continue };
            if parked.seq != reply.seq {
                // Stale reply (the park already timed out); ignore it but
                // put the newer park back.
                conn.parked = Some(parked);
                continue;
            }
            if reply.status >= 400 {
                self.ctx.telemetry.gateway_errors_total.inc();
            }
            conn.queue_json(reply.status, &reply.body, parked.keep_alive);
            // Un-parked: pipelined requests behind it may now proceed.
            self.advance(reply.conn);
        }
    }

    fn sweep_deadlines(&mut self) {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.parked.as_ref().is_some_and(|p| p.deadline <= now))
            .map(|(&t, _)| t)
            .collect();
        for token in expired {
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.parked = None;
                self.ctx.telemetry.gateway_errors_total.inc();
                conn.queue_json(
                    504,
                    &error_body("pipeline did not answer before the gateway timeout"),
                    false,
                );
            }
            self.flush_and_rearm(token);
        }
    }

    fn close(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            self.poller.del(conn.stream.as_raw_fd());
        }
    }
}

/// A routed pipeline command, before its [`Responder`] is attached (the
/// router has no access to the connection token).
enum CmdSpec {
    Infer(Tensor),
    Fleet,
    Stats,
    Policy,
    Deployments,
    Deploy(String),
    Undeploy(String),
    Migrate { model: String, from: usize, to: usize },
    Shutdown,
}

/// Value of `name` in a `k=v&k2=v2` query string, if present.
fn query_field<'a>(query: &'a str, name: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        (k == name).then_some(v)
    })
}

/// Decide what to do with one parsed request. Everything that needs the
/// pipeline becomes a command; everything else is answered here with a
/// typed status. Telemetry surfaces (`/metrics`, `/v1/traces`, the
/// dashboard) render straight off the shared registry on this thread.
fn route(req: &Request, ctx: &ServerCtx) -> Routed {
    let (path, query) = match req.target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.target.as_str(), ""),
    };
    match (req.method.as_str(), path) {
        ("GET", "/") => Routed::Raw(
            200,
            "text/html; charset=utf-8",
            DASHBOARD_HTML.as_bytes().to_vec(),
        ),
        ("GET", "/metrics") => Routed::Raw(
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            ctx.telemetry.render_prometheus().into_bytes(),
        ),
        ("GET", "/v1/traces") => {
            let doc = if query_field(query, "format") == Some("chrome") {
                ctx.telemetry.traces.chrome_all()
            } else {
                ctx.telemetry.traces.list_json()
            };
            Routed::Now(200, doc)
        }
        ("GET", t) if t.starts_with("/v1/traces/") => {
            let id = &t["/v1/traces/".len()..];
            match id.parse::<u64>() {
                Err(_) => Routed::Now(404, error_body(format!("bad trace id {id:?}"))),
                Ok(req_id) => {
                    let doc = if query_field(query, "format") == Some("chrome") {
                        ctx.telemetry.traces.get_chrome(req_id)
                    } else {
                        ctx.telemetry.traces.get_json(req_id)
                    };
                    match doc {
                        Some(v) => Routed::Now(200, v),
                        None => Routed::Now(
                            404,
                            error_body(format!("trace {req_id} is not retained")),
                        ),
                    }
                }
            }
        }
        ("GET", "/v1/healthz") => Routed::Now(
            200,
            json::obj(vec![
                ("ok", Value::Bool(true)),
                ("model", Value::Str(ctx.model.clone())),
                ("input_len", Value::Num(ctx.input_len as f64)),
            ]),
        ),
        ("GET", "/v1/fleet") => Routed::Cmd(CmdSpec::Fleet),
        ("GET", "/v1/stats") => Routed::Cmd(CmdSpec::Stats),
        ("GET", "/v1/policy") => Routed::Cmd(CmdSpec::Policy),
        ("GET", "/v1/deployments") => Routed::Cmd(CmdSpec::Deployments),
        ("POST", "/v1/infer") => match parse_infer(req, ctx) {
            Ok(input) => Routed::Cmd(CmdSpec::Infer(input)),
            Err(msg) => Routed::Now(400, error_body(msg)),
        },
        ("POST", "/v1/deployments") => match body_str_field(req, "model") {
            Ok(model) => Routed::Cmd(CmdSpec::Deploy(model)),
            Err(msg) => Routed::Now(400, error_body(msg)),
        },
        ("POST", "/v1/shutdown") => Routed::Cmd(CmdSpec::Shutdown),
        ("DELETE", t) if t.starts_with("/v1/deployments/") => {
            let model = &t["/v1/deployments/".len()..];
            if model.is_empty() || model.contains('/') {
                Routed::Now(404, error_body(format!("no such route: DELETE {t}")))
            } else {
                Routed::Cmd(CmdSpec::Undeploy(model.to_string()))
            }
        }
        ("POST", t)
            if t.starts_with("/v1/deployments/") && t.ends_with("/migrate") =>
        {
            let model = &t["/v1/deployments/".len()..t.len() - "/migrate".len()];
            if model.is_empty() || model.contains('/') {
                return Routed::Now(404, error_body(format!("no such route: POST {t}")));
            }
            match parse_migrate(req) {
                Ok((from, to)) => Routed::Cmd(CmdSpec::Migrate {
                    model: model.to_string(),
                    from,
                    to,
                }),
                Err(msg) => Routed::Now(400, error_body(msg)),
            }
        }
        (m, t) => {
            let known = matches!(
                t,
                "/"
                    | "/metrics"
                    | "/v1/traces"
                    | "/v1/healthz"
                    | "/v1/fleet"
                    | "/v1/stats"
                    | "/v1/policy"
                    | "/v1/deployments"
                    | "/v1/infer"
                    | "/v1/shutdown"
            ) || t.starts_with("/v1/deployments/")
                || t.starts_with("/v1/traces/");
            if known {
                Routed::Now(405, error_body(format!("method {m} not allowed on {t}")))
            } else {
                Routed::Now(404, error_body(format!("no such route: {m} {t}")))
            }
        }
    }
}

/// Attach the connection's reply handle to a routed command.
fn attach(spec: CmdSpec, resp: Responder) -> GatewayCmd {
    match spec {
        CmdSpec::Infer(input) => GatewayCmd::Infer { input, resp },
        CmdSpec::Fleet => GatewayCmd::Fleet { resp },
        CmdSpec::Stats => GatewayCmd::Stats { resp },
        CmdSpec::Policy => GatewayCmd::Policy { resp },
        CmdSpec::Deployments => GatewayCmd::Deployments { resp },
        CmdSpec::Deploy(model) => GatewayCmd::Deploy { model, resp },
        CmdSpec::Undeploy(model) => GatewayCmd::Undeploy { model, resp },
        CmdSpec::Migrate { model, from, to } => {
            GatewayCmd::Migrate { model, from, to, resp }
        }
        CmdSpec::Shutdown => GatewayCmd::Shutdown { resp: Some(resp) },
    }
}

fn parse_body_json(req: &Request) -> std::result::Result<Value, String> {
    let text = std::str::from_utf8(&req.body).map_err(|_| "body is not UTF-8")?;
    if text.trim().is_empty() {
        return Err("empty JSON body".to_string());
    }
    Value::parse(text).map_err(|e| format!("invalid JSON body: {e}"))
}

fn parse_infer(req: &Request, ctx: &ServerCtx) -> std::result::Result<Tensor, String> {
    let v = parse_body_json(req)?;
    let arr = v
        .get("input")
        .and_then(|x| x.as_arr().map(<[Value]>::to_vec))
        .map_err(|_| "body must be {\"input\": [numbers]}".to_string())?;
    if arr.len() != ctx.input_len {
        return Err(format!(
            "input length {} does not match model input length {}",
            arr.len(),
            ctx.input_len
        ));
    }
    let mut data = Vec::with_capacity(arr.len());
    for x in &arr {
        let f = x.as_f64().map_err(|_| "input entries must be numbers".to_string())?;
        if !f.is_finite() {
            return Err("input entries must be finite".to_string());
        }
        data.push(f as f32);
    }
    Tensor::new(vec![data.len()], data).map_err(|e| e.to_string())
}

fn body_str_field(req: &Request, field: &str) -> std::result::Result<String, String> {
    let v = parse_body_json(req)?;
    v.get(field)
        .and_then(Value::as_str)
        .map(str::to_string)
        .map_err(|_| format!("body must be {{\"{field}\": string}}"))
}

fn parse_migrate(req: &Request) -> std::result::Result<(usize, usize), String> {
    let v = parse_body_json(req)?;
    let from = v
        .get("from")
        .and_then(Value::as_usize)
        .map_err(|_| "body must be {\"from\": device, \"to\": device}".to_string())?;
    let to = v
        .get("to")
        .and_then(Value::as_usize)
        .map_err(|_| "body must be {\"from\": device, \"to\": device}".to_string())?;
    Ok((from, to))
}
