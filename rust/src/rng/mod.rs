//! Seedable PRNG + distributions substrate (offline: no `rand` crate).
//!
//! `Pcg32` (PCG-XSH-RR 64/32, O'Neill 2014) drives every stochastic element
//! of the fleet simulator — WiFi latency draws, failure schedules, workload
//! generators — so every experiment is reproducible from a single seed.
//! Distributions implemented are the ones the network model needs:
//! uniform, Bernoulli, exponential, normal (Box–Muller), lognormal, and
//! Pareto (the heavy tail of Fig. 1).

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-arg convenience (stream 54).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Derive an independent child generator (for per-device streams).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(2654435761).wrapping_add(1))
    }

    /// Next 32 uniform bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift with rejection for unbiased results.
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Bernoulli draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/stddev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto with scale x_m and shape alpha (heavy tail for WiFi outliers).
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        x_m / u.powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        let mut c = Pcg32::new(42, 2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut r = Pcg32::seeded(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        // Median of lognormal(mu, sigma) is exp(mu).
        let mut r = Pcg32::seeded(11);
        let mut xs: Vec<f64> = (0..20_001).map(|_| r.lognormal(1.0, 0.8)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[10_000];
        assert!((med - 1.0f64.exp()).abs() < 0.15, "median={med}");
    }

    #[test]
    fn pareto_is_heavy_tailed_and_bounded_below() {
        let mut r = Pcg32::seeded(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.pareto(1.0, 2.0)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let p99 = {
            let mut s = xs.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[(0.99 * s.len() as f64) as usize]
        };
        assert!(p99 > 5.0, "p99={p99}"); // sqrt(100) = 10 expected scale
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg32::seeded(17);
        let mut xs: Vec<usize> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(xs, (0..20).collect::<Vec<_>>());
    }
}
