//! # cdc-dnn — Robust distributed DNN inference with Coded Distributed Computing
//!
//! Reproduction of Hadidi, Cao & Kim, *"Creating Robust Deep Neural
//! Networks With Coded Distributed Computing for IoT Systems"* (2021).
//!
//! The crate is the L3 coordinator of a three-layer stack (see DESIGN.md):
//! JAX/Pallas author the per-device GEMM programs at build time; this crate
//! loads the AOT artifacts via PJRT, distributes single-batch inference
//! across a (simulated) IoT fleet with the paper's model-parallel splitting
//! methods, and makes the system robust to device failure/stragglers with
//! one extra *coded* device per layer whose weights are the offline sum of
//! the data shards — recovery is a local subtraction, cost is constant in
//! fleet size.
//!
//! ## Quickstart (doctested)
//!
//! The flow documented in `examples/quickstart.rs`, here on the
//! synthetic artifact set so `cargo test` runs it with no AOT build —
//! deploy with a CDC parity device, kill a device, and the request
//! survives with an *identical* answer:
//!
//! ```
//! use cdc_dnn::coordinator::{Session, SessionConfig, SplitSpec};
//! use cdc_dnn::fleet::FailurePlan;
//! use cdc_dnn::testkit::synth;
//!
//! # fn main() -> cdc_dnn::Result<()> {
//! let artifacts = synth::build(7)?;           // or `make artifacts` + "artifacts/"
//! let mut cfg = SessionConfig::new(synth::MODEL);
//! cfg.n_devices = 2;
//! cfg.splits.insert("fc1".into(), SplitSpec::cdc(2));
//! let mut session = Session::start(&artifacts.root, cfg)?;
//!
//! let x = cdc_dnn::Tensor::randn(vec![synth::FC1_K], &mut cdc_dnn::rng::Pcg32::seeded(1));
//! let healthy = session.infer(&x)?;
//! session.set_failure(1, FailurePlan::PermanentAt(0))?;   // device dies
//! let recovered = session.infer(&x)?;
//! assert!(recovered.any_recovery);
//! // Recovery is a local subtraction — same prediction, no lost request.
//! assert_eq!(healthy.output.argmax(), recovered.output.argmax());
//! assert!(healthy.output.max_abs_diff(&recovered.output) < 1e-4);
//! # Ok(()) }
//! ```
//!
//! Pipelined serving (`examples/e2e_serving.rs`) drives a whole
//! [`coordinator::Workload`] through the same session —
//! `session.serve(&Workload::closed(inputs, 4))` — and the scenario
//! engine ([`scenario`]) scripts time-varying fleet chaos on top; see
//! `docs/EXPERIMENTS.md` for the full experiment book.
//!
//! Everything above runs over the virtual-time simulator by default;
//! setting `SessionConfig::transport` to [`transport::TransportSpec::Tcp`]
//! serves the same session over **real TCP worker processes**
//! (`cdc-dnn worker`) with wall-clock timing and real process-kill
//! failure injection — see [`transport`] and DESIGN.md §11.

pub mod cdc;
pub mod coordinator;
pub mod bench;
pub mod config;
pub mod error;
pub mod exp;
pub mod fleet;
pub mod gateway;
pub mod json;
pub mod kernels;
pub mod model;
pub mod partition;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod telemetry;
pub mod testkit;
pub mod tensor;
pub mod transport;

pub use error::{Error, Result};
pub use tensor::Tensor;
