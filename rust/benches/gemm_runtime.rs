//! Micro-benchmarks of the runtime hot path: PJRT artifact execution for
//! the shard shapes the paper's deployments use, the XlaBuilder fallback,
//! and the coordinator-side merge ops (CDC decode must be "close-to-zero"
//! next to a shard execution — this bench substantiates that claim).

use cdc_dnn::bench::Bench;
use cdc_dnn::cdc;
use cdc_dnn::rng::Pcg32;
use cdc_dnn::runtime::{Manifest, Runtime};
use cdc_dnn::tensor::Tensor;

fn main() {
    if !cdc_dnn::testkit::artifacts_available(std::path::Path::new("artifacts")) {
        return;
    }
    let manifest = Manifest::load("artifacts").expect("run `make artifacts`");
    let runtime = Runtime::new().expect("pjrt");
    let mut rng = Pcg32::seeded(1);

    // --- fc-2048 shard (the paper's §6 anchor task), 4-way split ------
    if manifest.artifacts.contains_key("fc_m512_k2048_lin") {
        let w = Tensor::randn(vec![512, 2048], &mut rng);
        let b = Tensor::randn(vec![512, 1], &mut rng);
        let x = Tensor::randn(vec![2048, 1], &mut rng);
        runtime.execute(&manifest, "fc_m512_k2048_lin", &[&w, &b, &x]).unwrap();
        Bench::new("pjrt_exec/fc2048_shard_d4 (512x2048)").run(|| {
            runtime
                .execute(&manifest, "fc_m512_k2048_lin", &[&w, &b, &x])
                .unwrap();
        });
        // XlaBuilder fallback of the same GEMM, for comparison.
        let exe = runtime.build_gemm(512, 2048, 1, true, false).unwrap();
        Bench::new("pjrt_exec/fc2048_shard_builder_fallback").run(|| {
            runtime.run_built(&exe, &[&w, &x, &b]).unwrap();
        });
    }

    // --- LeNet conv shard --------------------------------------------
    if let Some(meta) = manifest
        .artifacts
        .values()
        .find(|a| a.name.starts_with("conv_h14w14c6_k16"))
        .cloned()
    {
        let ins: Vec<Tensor> =
            meta.params.iter().map(|p| Tensor::randn(p.clone(), &mut rng)).collect();
        let refs: Vec<&Tensor> = ins.iter().collect();
        runtime.execute(&manifest, &meta.name, &refs).unwrap();
        Bench::new("pjrt_exec/lenet_conv2_shard").run(|| {
            runtime.execute(&manifest, &meta.name, &refs).unwrap();
        });
    }

    // --- merge-path ops: the "close-to-zero" recovery claim ------------
    let parity = Tensor::randn(vec![512, 1], &mut rng);
    let received: Vec<Tensor> =
        (0..3).map(|_| Tensor::randn(vec![512, 1], &mut rng)).collect();
    let refs: Vec<&Tensor> = received.iter().collect();
    Bench::new("merge/cdc_decode_512 (recovery subtraction)")
        .iters(100, 1000)
        .run(|| {
            cdc::decode(&parity, &refs).unwrap();
        });

    let parts: Vec<Tensor> =
        (0..4).map(|_| Tensor::randn(vec![512, 1], &mut rng)).collect();
    let prefs: Vec<&Tensor> = parts.iter().collect();
    Bench::new("merge/concat0_4x512").iters(100, 1000).run(|| {
        Tensor::concat0(&prefs).unwrap().take_rows(2048).unwrap();
    });

    let conv_parts: Vec<Tensor> =
        (0..2).map(|_| Tensor::randn(vec![28, 28, 8], &mut rng)).collect();
    let crefs: Vec<&Tensor> = conv_parts.iter().collect();
    Bench::new("merge/concat_channels+pool 28x28x16")
        .iters(100, 1000)
        .run(|| {
            let cat = Tensor::concat_channels(&crefs).unwrap();
            cat.maxpool(2, 2).unwrap();
        });
}
