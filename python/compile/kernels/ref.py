"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in :mod:`compile.kernels.gemm` has a reference implementation
here written with plain ``jax.numpy`` ops only. The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-driven shape and
value sweeps — this is the core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(w, x, bias=None, relu=False):
    """Reference GEMM with optional fused bias + ReLU epilogue.

    ``w``: (m, k) weight shard, ``x``: (k, n) input, ``bias``: (m, 1) or None.
    Mirrors the paper's Eq. 3 (fc) and Eq. 4 (conv-as-GEMM) per-device task.
    """
    out = jnp.dot(w, x, preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def cdc_encode_ref(shards):
    """Reference CDC parity-weight construction (paper Eq. 11).

    ``shards``: (d, m_s, k) stack of per-device weight shards. The parity
    device's weights are the elementwise sum over the device axis, computed
    offline — independent of inputs.
    """
    return jnp.sum(shards, axis=0)


def cdc_decode_ref(parity_out, received):
    """Reference CDC recovery (paper §5.2): missing = parity − Σ received.

    ``parity_out``: (m_s, n) output of the parity device; ``received``:
    (d-1, m_s, n) outputs of the surviving devices. Returns the reconstructed
    output of the single missing device.
    """
    return parity_out - jnp.sum(received, axis=0)


def im2col_ref(x, fh, fw, stride=1, padding="SAME"):
    """Reference patch-unroll (paper Fig. 4): (H, W, C) → (F²C, OH·OW).

    Column j holds the unrolled receptive field of output pixel j, so that
    ``W_{K×F²C} @ im2col(x)`` equals the convolution output (Eq. 4).
    """
    h, w, c = x.shape
    if padding == "SAME":
        oh = -(-h // stride)
        ow = -(-w // stride)
        ph = max((oh - 1) * stride + fh - h, 0)
        pw = max((ow - 1) * stride + fw - w, 0)
        x = jnp.pad(x, ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)))
    elif padding == "VALID":
        oh = (h - fh) // stride + 1
        ow = (w - fw) // stride + 1
    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"unknown padding {padding!r}")
    cols = []
    for i in range(oh):
        for j in range(ow):
            patch = x[i * stride : i * stride + fh, j * stride : j * stride + fw, :]
            cols.append(patch.reshape(-1))
    return jnp.stack(cols, axis=1)


def conv2d_ref(x, w, bias=None, stride=1, padding="SAME", relu=False):
    """Reference convolution via im2col + GEMM.

    ``x``: (H, W, C), ``w``: (K, F, F, C) filters, ``bias``: (K,) or None.
    Returns (OH, OW, K).
    """
    k, fh, fw, _c = w.shape
    cols = im2col_ref(x, fh, fw, stride=stride, padding=padding)
    wmat = w.reshape(k, -1)
    b = bias.reshape(k, 1) if bias is not None else None
    out = gemm_ref(wmat, cols, bias=b, relu=relu)  # (K, OH*OW)
    h, wdt, _ = x.shape
    if padding == "SAME":
        oh = -(-h // stride)
        ow = -(-wdt // stride)
    else:
        oh = (h - fh) // stride + 1
        ow = (wdt - fw) // stride + 1
    return out.reshape(k, oh, ow).transpose(1, 2, 0)


def maxpool_ref(x, size=2, stride=2):
    """Reference max-pool over (H, W, C); VALID padding, square window."""
    h, w, c = x.shape
    oh = (h - size) // stride + 1
    ow = (w - size) // stride + 1
    out = jnp.full((oh, ow, c), -jnp.inf, dtype=x.dtype)
    for di in range(size):
        for dj in range(size):
            out = jnp.maximum(
                out, x[di : di + oh * stride : stride, dj : dj + ow * stride : stride, :]
            )
    return out.astype(x.dtype)
