//! Coordinator-side TCP transport: persistent per-device connections,
//! per-connection reader threads, and the reply-reaper.
//!
//! ## Liveness invariant
//!
//! The serve engine blocks until every dispatched task has a
//! completion. Over real sockets three things can eat a reply: a slow
//! worker (straggler), a worker that died mid-request (SIGKILL), and a
//! write into a dead connection. Each is converted into a synthesised
//! lost completion (`result: None`, `t_arrival = ∞`) — exactly the
//! shape the simulator delivers for a dropped reply, so the policy /
//! CDC-recovery layers run unchanged:
//!
//! * **deadline reaper**: every dispatched task carries a wall-clock
//!   deadline (`TcpConfig::order_deadline_ms` after dispatch); a
//!   background thread reaps overdue tasks. This is the straggler gate
//!   on real time — CDC then substitutes the parity result without
//!   waiting, the paper's zero-latency recovery.
//! * **connection death**: a reader thread hitting EOF/error marks the
//!   device dead and synthesises losses for everything outstanding on
//!   it — a killed worker process is detected at TCP speed, not at the
//!   deadline.
//! * **dispatch to a dead device**: synthesises losses immediately
//!   (mirrors the simulator, where a failed device still "answers"
//!   with `∞`).
//!
//! Late replies that arrive after their task was reaped are dropped on
//! the reader thread (the task is no longer outstanding), so a task
//! never yields two completions.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::fleet::{Completion, FailurePlan, NetConfig, TaskDef, WorkOrder};

use super::wire::{self, Frame};
use super::{TcpConfig, Transport};

/// Lock a mutex, recovering from poisoning (a panicked reader thread
/// must not cascade into the coordinator).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One dispatched, not-yet-answered task.
struct OutTask {
    device: usize,
    deadline_ms: f64,
}

/// Mutable transport state shared with the reader/reaper threads.
struct State {
    /// Per-device liveness (false once the connection died).
    alive: Vec<bool>,
    /// (req, task) → in-flight bookkeeping.
    outstanding: BTreeMap<(u64, u64), OutTask>,
}

struct Inner {
    epoch: Mutex<Instant>,
    state: Mutex<State>,
    tx: Sender<Completion>,
    stop: AtomicBool,
}

impl Inner {
    fn now_ms(&self) -> f64 {
        lock(&self.epoch).elapsed().as_secs_f64() * 1e3
    }

    /// Synthesise a lost completion (the wire twin of the simulator's
    /// `t_arrival = ∞` delivery).
    fn send_lost(&self, req: u64, task: u64, device: usize) {
        let _ = self.tx.send(Completion {
            req,
            task,
            device,
            result: None,
            t_arrival_ms: f64::INFINITY,
        });
    }

    /// Mark a device's connection dead and synthesise losses for all of
    /// its outstanding tasks. Idempotent.
    fn mark_dead(&self, device: usize) {
        let mut st = lock(&self.state);
        if !st.alive[device] {
            return;
        }
        st.alive[device] = false;
        let dead: Vec<(u64, u64)> = st
            .outstanding
            .iter()
            .filter(|(_, o)| o.device == device)
            .map(|(&k, _)| k)
            .collect();
        for (req, task) in dead {
            st.outstanding.remove(&(req, task));
            self.send_lost(req, task, device);
        }
    }
}

/// Real-execution transport over per-device TCP connections.
pub struct TcpTransport {
    inner: Arc<Inner>,
    /// Writer halves, one per device, frame-atomic via the mutex.
    writers: Vec<Mutex<TcpStream>>,
    rx: Receiver<Completion>,
    threads: Vec<JoinHandle<()>>,
    deadline_ms: f64,
}

impl TcpTransport {
    /// Connect to the first `n_devices` workers of `cfg.workers`,
    /// handshake each, and start the reader + reaper threads.
    pub fn connect(cfg: &TcpConfig, n_devices: usize, seed: u64) -> Result<TcpTransport> {
        if cfg.workers.len() < n_devices {
            return Err(Error::Config(format!(
                "tcp transport: {} worker address(es) for {} devices \
                 (data + redundancy) — start more workers or list more \
                 addresses in the deployment's transport section",
                cfg.workers.len(),
                n_devices
            )));
        }
        let timeout = Duration::from_millis(cfg.connect_timeout_ms.max(1));
        let (tx, rx) = channel();
        let inner = Arc::new(Inner {
            epoch: Mutex::new(Instant::now()),
            state: Mutex::new(State {
                alive: vec![true; n_devices],
                outstanding: BTreeMap::new(),
            }),
            tx,
            stop: AtomicBool::new(false),
        });

        // Build the transport incrementally so a partial connect/
        // handshake failure drops it — Drop sets the stop flag, shuts
        // the already-open sockets down, and joins the already-spawned
        // reader threads (no wedged workers or leaked readers).
        let mut t = TcpTransport {
            inner,
            writers: Vec::with_capacity(n_devices),
            rx,
            threads: Vec::new(),
            deadline_ms: cfg.order_deadline_ms.max(1.0),
        };
        for (device, addr) in cfg.workers.iter().take(n_devices).enumerate() {
            let stream = connect_one(addr, timeout)?;
            stream
                .set_nodelay(true)
                .map_err(|e| Error::Wire(format!("{addr}: set_nodelay: {e}")))?;
            // Handshake under a read timeout so a wedged worker fails
            // fast; cleared before the reader thread takes over.
            stream
                .set_read_timeout(Some(timeout))
                .map_err(|e| Error::Wire(format!("{addr}: set timeout: {e}")))?;
            let mut hs = stream
                .try_clone()
                .map_err(|e| Error::Wire(format!("{addr}: clone stream: {e}")))?;
            wire::write_frame(&mut hs, &wire::hello(seed, device as u32))?;
            match wire::read_frame(&mut hs)? {
                Some(Frame::HelloAck { proto }) if proto == wire::PROTO_VERSION => {}
                Some(Frame::HelloAck { proto }) => {
                    return Err(Error::Wire(format!(
                        "{addr}: protocol version {proto} != {}",
                        wire::PROTO_VERSION
                    )))
                }
                other => {
                    return Err(Error::Wire(format!(
                        "{addr}: bad handshake reply: {other:?}"
                    )))
                }
            }
            stream
                .set_read_timeout(None)
                .map_err(|e| Error::Wire(format!("{addr}: clear timeout: {e}")))?;

            let reader = stream
                .try_clone()
                .map_err(|e| Error::Wire(format!("{addr}: clone stream: {e}")))?;
            let inner2 = t.inner.clone();
            t.threads.push(
                std::thread::Builder::new()
                    .name(format!("tcp-reader-{device}"))
                    .spawn(move || reader_main(reader, device, inner2))
                    .map_err(|e| Error::Fleet(format!("spawn reader {device}: {e}")))?,
            );
            t.writers.push(Mutex::new(stream));
        }

        let inner2 = t.inner.clone();
        let tick = Duration::from_millis(cfg.reaper_tick_ms.max(1));
        t.threads.push(
            std::thread::Builder::new()
                .name("tcp-reaper".into())
                .spawn(move || reaper_main(inner2, tick))
                .map_err(|e| Error::Fleet(format!("spawn reaper: {e}")))?,
        );

        Ok(t)
    }

    /// Per-device liveness snapshot (tests / diagnostics).
    pub fn alive(&self) -> Vec<bool> {
        lock(&self.inner.state).alive.clone()
    }

    /// Write a pre-encoded frame to a device; on failure the device is
    /// marked dead (synthesising losses for its in-flight work) and
    /// `false` is returned.
    fn write(&self, device: usize, frame: &[u8]) -> bool {
        let ok = {
            let mut w = lock(&self.writers[device]);
            w.write_all(frame).and_then(|_| w.flush()).is_ok()
        };
        if !ok {
            self.inner.mark_dead(device);
        }
        ok
    }

    fn check_device(&self, device: usize) -> Result<()> {
        if device >= self.writers.len() {
            return Err(Error::Config(format!("no device {device}")));
        }
        Ok(())
    }
}

impl Transport for TcpTransport {
    fn label(&self) -> &'static str {
        "tcp"
    }

    fn wall_clock(&self) -> bool {
        true
    }

    fn now_ms(&self) -> f64 {
        self.inner.now_ms()
    }

    fn begin_serve(&self) {
        // Orphans of a previous serve (late replies, reaped stragglers)
        // must not leak into this run's gather loop or deadlines.
        {
            let mut st = lock(&self.inner.state);
            st.outstanding.clear();
        }
        while self.rx.try_recv().is_ok() {}
        *lock(&self.inner.epoch) = Instant::now();
    }

    fn pace(&self, t_ms: f64) {
        let now = self.now_ms();
        if t_ms > now {
            std::thread::sleep(Duration::from_secs_f64((t_ms - now) / 1e3));
        }
    }

    fn clamp_ms(&self, t_ms: f64) -> f64 {
        t_ms.max(self.now_ms())
    }

    fn n_devices(&self) -> usize {
        self.writers.len()
    }

    fn deploy(&self, device: usize, tasks: Vec<TaskDef>) -> Result<()> {
        self.check_device(device)?;
        if !lock(&self.inner.state).alive[device] {
            return Err(Error::Fleet(format!("device {device} is gone")));
        }
        // One frame per task so a device's whole shard set can exceed
        // the frame cap without tripping it; a single shard that still
        // does gets a diagnosis *before* encoding (the encoder asserts
        // the cap) instead of a dead connection.
        for task in &tasks {
            let payload = 4 * (task.w.len() + task.b.len()) + task.artifact.len() + 128;
            if payload > wire::MAX_FRAME_LEN as usize {
                return Err(Error::Config(format!(
                    "task {}: ~{payload} bytes of weights exceed the wire frame \
                     cap ({} bytes) — split the layer over more devices",
                    task.id,
                    wire::MAX_FRAME_LEN
                )));
            }
            let frame = wire::deploy(std::slice::from_ref(task));
            if !self.write(device, &frame) {
                return Err(Error::Fleet(format!("device {device}: deploy failed")));
            }
        }
        Ok(())
    }

    fn undeploy(&self, device: usize, task_ids: Vec<u64>) -> Result<()> {
        self.check_device(device)?;
        // Best effort: undeploying from a dead device is a no-op.
        let frame = wire::undeploy(&task_ids);
        if lock(&self.inner.state).alive[device] {
            self.write(device, &frame);
        }
        Ok(())
    }

    fn dispatch(&self, device: usize, order: WorkOrder) -> Result<()> {
        self.check_device(device)?;
        let deadline_ms = self.now_ms() + self.deadline_ms;
        {
            let mut st = lock(&self.inner.state);
            if !st.alive[device] {
                // A dead device still "answers": synthesised losses keep
                // the gather loop's completion count exact.
                drop(st);
                for &t in &order.tasks {
                    self.inner.send_lost(order.req, t, device);
                }
                return Ok(());
            }
            for &t in &order.tasks {
                st.outstanding.insert((order.req, t), OutTask { device, deadline_ms });
            }
        }
        let frame =
            wire::work(order.req, &order.tasks, order.batch, order.input.as_ref());
        // On write failure mark_dead has already reaped the tasks
        // registered above — dispatch still succeeds from the engine's
        // point of view (the losses are in the completion stream).
        self.write(device, &frame);
        Ok(())
    }

    fn recv(&self) -> Result<Completion> {
        self.rx
            .recv()
            .map_err(|_| Error::Fleet("tcp completion channel closed".into()))
    }

    fn recv_deadline(&self, until_ms: f64) -> Result<Option<Completion>> {
        let now = self.now_ms();
        if until_ms <= now {
            return Ok(self.rx.try_recv().ok());
        }
        let dur = Duration::from_secs_f64((until_ms - now) / 1e3);
        match self.rx.recv_timeout(dur) {
            Ok(c) => Ok(Some(c)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Fleet("tcp completion channel closed".into()))
            }
        }
    }

    fn try_recv(&self) -> Option<Completion> {
        self.rx.try_recv().ok()
    }

    fn set_failure(&self, device: usize, plan: FailurePlan) -> Result<()> {
        self.check_device(device)?;
        if lock(&self.inner.state).alive[device] {
            self.write(device, &wire::set_failure(&plan));
        }
        Ok(())
    }

    fn set_net(&self, device: usize, net: NetConfig) -> Result<()> {
        self.check_device(device)?;
        if lock(&self.inner.state).alive[device] {
            self.write(device, &wire::set_net(true, &net));
        }
        Ok(())
    }

    fn set_rate(&self, device: usize, macs_per_ms: f64) -> Result<()> {
        self.check_device(device)?;
        if lock(&self.inner.state).alive[device] {
            self.write(device, &wire::set_rate(macs_per_ms));
        }
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        // Closing the sockets unblocks the reader threads; workers
        // return to their accept loop (they are NOT shut down — the
        // loopback harness owns child lifetimes, and standalone workers
        // keep serving the next coordinator).
        for w in &self.writers {
            let _ = lock(w).shutdown(std::net::Shutdown::Both);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn connect_one(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let mut last = Error::Wire(format!("{addr}: no addresses resolved"));
    let resolved = addr
        .to_socket_addrs()
        .map_err(|e| Error::Wire(format!("{addr}: resolve: {e}")))?;
    for sa in resolved {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Error::Wire(format!("{addr}: connect: {e}")),
        }
    }
    Err(last)
}

/// Reader thread: parse reply frames, stamp receipt time, forward
/// completions for tasks still outstanding; on EOF/error mark the
/// device dead.
fn reader_main(mut stream: TcpStream, device: usize, inner: Arc<Inner>) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(Frame::Reply { req, task, result })) => {
                let now = inner.now_ms();
                let known = {
                    let mut st = lock(&inner.state);
                    st.outstanding.remove(&(req, task)).is_some()
                };
                if !known {
                    continue; // late reply, already reaped — drop it
                }
                let lost = result.is_none();
                let t_arrival_ms = if lost { f64::INFINITY } else { now };
                let _ = inner.tx.send(Completion { req, task, device, result, t_arrival_ms });
            }
            Ok(Some(_)) => {
                // A worker must only speak Reply after the handshake;
                // anything else is a protocol violation.
                inner.mark_dead(device);
                break;
            }
            Ok(None) | Err(_) => {
                inner.mark_dead(device);
                break;
            }
        }
        if inner.stop.load(Ordering::SeqCst) {
            break;
        }
    }
}

/// Reaper thread: synthesise losses for tasks past their deadline —
/// the wall-clock straggler gate.
fn reaper_main(inner: Arc<Inner>, tick: Duration) {
    while !inner.stop.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        let now = inner.now_ms();
        let expired: Vec<(u64, u64, usize)> = {
            let mut st = lock(&inner.state);
            let keys: Vec<(u64, u64, usize)> = st
                .outstanding
                .iter()
                .filter(|(_, o)| o.deadline_ms <= now)
                .map(|(&(req, task), o)| (req, task, o.device))
                .collect();
            for &(req, task, _) in &keys {
                st.outstanding.remove(&(req, task));
            }
            keys
        };
        for (req, task, device) in expired {
            inner.send_lost(req, task, device);
        }
    }
}
